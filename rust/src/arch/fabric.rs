//! Heterogeneous fabric model: per-directed-link service times and
//! controller-placement strategies.
//!
//! The seed simulator billed every mesh link at the single scalar
//! `LatencyParams::link_service` and pinned memory controllers to evenly
//! spaced top/bottom edge slots. Real meshes are not uniform: chips ship
//! express rows/columns that bypass intermediate routers, wider links along
//! the die edge, per-direction asymmetry (e.g. the Epiphany eMesh, whose
//! writes stream faster than reads), and controllers at corners, sides, or
//! interior TSV sites. Where the controllers sit and how expensive each
//! link is decides *which* routes the coherence protocol saturates — the
//! mechanism behind the paper's Fig. 4 crossover and the traffic analysis
//! of Kommrusch et al. (arXiv:2011.05422).
//!
//! Three types model this:
//!
//! - [`Fabric`] — the per-machine table giving every directed link its own
//!   service time (indexed by `Machine::link_index`). A uniform table with
//!   the machine's scalar `link_service` reproduces the pre-fabric billing
//!   exactly (property-pinned by `rust/tests/prop_fabric.rs`).
//! - [`CtrlPlacement`] — where the memory controllers attach:
//!   `EdgesEven` (the seed's top/bottom spacing, the default), `Sides`,
//!   `Corners`, `Interior`, or an explicit tile list.
//! - [`FabricSpec`] — a compact, parseable description carried by
//!   `RunSpec`s and the `--fabric` CLI flag, e.g.
//!   `8x8:ctrl=corners:express-row=3@0.5`.

use super::machine::{Machine, MachineSpec};
use super::topology::{Controller, Dir, TileId};

/// Errors from parsing a [`FabricSpec`] / [`CtrlPlacement`] or applying
/// one to a machine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FabricError {
    /// The spec string itself is malformed.
    BadSpec { spec: String, why: String },
    /// A structurally valid spec does not fit the target machine
    /// (out-of-range row/column, too many controllers for a placement, …).
    Incompatible { what: String, why: String },
}

impl std::fmt::Display for FabricError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FabricError::BadSpec { spec, why } => {
                write!(f, "bad fabric spec '{spec}': {why}")
            }
            FabricError::Incompatible { what, why } => {
                write!(f, "fabric '{what}' does not fit this machine: {why}")
            }
        }
    }
}

impl std::error::Error for FabricError {}

fn bad(spec: &str, why: impl Into<String>) -> FabricError {
    FabricError::BadSpec {
        spec: spec.to_string(),
        why: why.into(),
    }
}

// ---------------------------------------------------------------------------
// Fabric: the per-link service table
// ---------------------------------------------------------------------------

/// Per-directed-link service times of one machine, indexed by
/// `Machine::link_index`. Service 0 models an infinitely wide (express)
/// link: it still carries traffic but never queues.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fabric {
    service: Vec<u64>,
}

impl Fabric {
    /// A uniform fabric: every link bills `service` cycles — the
    /// pre-fabric scalar model.
    pub fn uniform(num_links: usize, service: u64) -> Fabric {
        Fabric {
            service: vec![service; num_links],
        }
    }

    /// A fabric from an explicit per-link table.
    pub fn from_services(service: Vec<u64>) -> Fabric {
        Fabric { service }
    }

    #[inline]
    pub fn num_links(&self) -> usize {
        self.service.len()
    }

    /// Service time of the directed link at `index`.
    #[inline]
    pub fn service(&self, index: usize) -> u64 {
        self.service[index]
    }

    /// `Some(service)` when every link bills the same value (the scalar
    /// model), `None` for a heterogeneous table.
    pub fn uniform_service(&self) -> Option<u64> {
        let first = *self.service.first()?;
        self.service.iter().all(|&s| s == first).then_some(first)
    }

    /// Sort-and-group a stream of service values into `(service, count)`
    /// classes, cheapest first (shared by [`classes`](Self::classes) and
    /// the physical-link grouping in `metrics`).
    pub fn classes_of(services: impl Iterator<Item = u64>) -> Vec<(u64, usize)> {
        let mut sorted: Vec<u64> = services.collect();
        sorted.sort_unstable();
        let mut out: Vec<(u64, usize)> = Vec::new();
        for s in sorted {
            match out.last_mut() {
                Some((v, n)) if *v == s => *n += 1,
                _ => out.push((s, 1)),
            }
        }
        out
    }

    /// Distinct service values with their *table-slot* counts, cheapest
    /// first. Counts include the off-grid boundary slots that never carry
    /// traffic (every tile owns four entries); `metrics` recomputes the
    /// classes over physical links (via `Machine::has_link`) for the
    /// heatmap annotations.
    pub fn classes(&self) -> Vec<(u64, usize)> {
        Fabric::classes_of(self.service.iter().copied())
    }
}

// ---------------------------------------------------------------------------
// Controller placement strategies
// ---------------------------------------------------------------------------

/// Where a machine's memory controllers attach to the mesh.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CtrlPlacement {
    /// Evenly spaced on the top and bottom edges (the TILEPro64 pattern
    /// and the pre-fabric default — byte-identical controller lists).
    EdgesEven,
    /// Evenly spaced on the left and right edges.
    Sides,
    /// At the grid corners (at most the number of distinct corners).
    Corners,
    /// Evenly spaced along the middle row — interior TSV-style attach
    /// points (degenerate 1-row grids fall back to that single row).
    Interior,
    /// An explicit list of attach tiles; the list length is the
    /// controller count.
    Explicit(Vec<TileId>),
}

impl CtrlPlacement {
    /// Parse a placement clause: `edges | sides | corners | interior` or
    /// an explicit `+`-separated tile list like `0+27+63`.
    pub fn parse(s: &str) -> Result<CtrlPlacement, FabricError> {
        match s {
            "edges" => return Ok(CtrlPlacement::EdgesEven),
            "sides" => return Ok(CtrlPlacement::Sides),
            "corners" => return Ok(CtrlPlacement::Corners),
            "interior" => return Ok(CtrlPlacement::Interior),
            _ => {}
        }
        let tiles: Option<Vec<TileId>> = s
            .split('+')
            .map(|t| t.parse::<u32>().ok().map(TileId))
            .collect();
        match tiles {
            Some(ts) if !ts.is_empty() => Ok(CtrlPlacement::Explicit(ts)),
            _ => Err(bad(
                s,
                "want edges | sides | corners | interior | tile+tile+…",
            )),
        }
    }

    /// Stable label (the parser's inverse).
    pub fn label(&self) -> String {
        match self {
            CtrlPlacement::EdgesEven => "edges".into(),
            CtrlPlacement::Sides => "sides".into(),
            CtrlPlacement::Corners => "corners".into(),
            CtrlPlacement::Interior => "interior".into(),
            CtrlPlacement::Explicit(ts) => ts
                .iter()
                .map(|t| t.0.to_string())
                .collect::<Vec<_>>()
                .join("+"),
        }
    }

    /// The distinct corner tiles of a `w×h` grid, spread-first order
    /// (opposite corners before adjacent ones).
    fn corner_tiles(w: u32, h: u32) -> Vec<TileId> {
        let mut out: Vec<TileId> = Vec::with_capacity(4);
        for t in [
            TileId(0),
            TileId((h - 1) * w + (w - 1)),
            TileId(w - 1),
            TileId((h - 1) * w),
        ] {
            if !out.contains(&t) {
                out.push(t);
            }
        }
        out
    }

    /// Maximum controller count this placement supports on a `w×h` grid
    /// (every attach tile must be distinct — stacking controllers on one
    /// tile would double the modelled DRAM bandwidth there).
    pub fn capacity(&self, w: u32, h: u32) -> u32 {
        match self {
            CtrlPlacement::EdgesEven => {
                if h == 1 {
                    w
                } else {
                    2 * w
                }
            }
            CtrlPlacement::Sides => {
                if w == 1 {
                    h
                } else {
                    2 * h
                }
            }
            CtrlPlacement::Corners => CtrlPlacement::corner_tiles(w, h).len() as u32,
            CtrlPlacement::Interior => w,
            CtrlPlacement::Explicit(ts) => ts.len() as u32,
        }
    }

    /// Build the controller list for `ctrls` controllers on a `w×h` grid.
    /// `Explicit` ignores `ctrls` (its list is the count). `EdgesEven`
    /// reproduces the pre-fabric attach columns exactly.
    pub fn controllers(&self, w: u32, h: u32, ctrls: u32) -> Result<Vec<Controller>, FabricError> {
        let n = match self {
            CtrlPlacement::Explicit(ts) => ts.len() as u32,
            _ => ctrls,
        };
        if n == 0 || n > self.capacity(w, h) {
            return Err(FabricError::Incompatible {
                what: format!("ctrl={}", self.label()),
                why: format!(
                    "{n} controller(s) on a {w}x{h} grid: this placement holds 1..={}",
                    self.capacity(w, h)
                ),
            });
        }
        // Evenly spaced index along an axis of length `len` — injective
        // for counts up to `len` (the seed's edge-column formula).
        let spread = |j: u32, count: u32, len: u32| ((j + 1) * len / (count + 1)).min(len - 1);
        let mut cs: Vec<Controller> = Vec::with_capacity(n as usize);
        match self {
            CtrlPlacement::EdgesEven => {
                let top = if h == 1 { n } else { n.div_ceil(2) };
                let bottom = n - top;
                for j in 0..top {
                    cs.push(Controller {
                        id: j,
                        attach: TileId(spread(j, top, w)),
                    });
                }
                for j in 0..bottom {
                    cs.push(Controller {
                        id: top + j,
                        attach: TileId((h - 1) * w + spread(j, bottom, w)),
                    });
                }
            }
            CtrlPlacement::Sides => {
                let left = if w == 1 { n } else { n.div_ceil(2) };
                let right = n - left;
                for j in 0..left {
                    cs.push(Controller {
                        id: j,
                        attach: TileId(spread(j, left, h) * w),
                    });
                }
                for j in 0..right {
                    cs.push(Controller {
                        id: left + j,
                        attach: TileId(spread(j, right, h) * w + (w - 1)),
                    });
                }
            }
            CtrlPlacement::Corners => {
                for (j, t) in CtrlPlacement::corner_tiles(w, h)
                    .into_iter()
                    .take(n as usize)
                    .enumerate()
                {
                    cs.push(Controller {
                        id: j as u32,
                        attach: t,
                    });
                }
            }
            CtrlPlacement::Interior => {
                let row = h / 2;
                for j in 0..n {
                    cs.push(Controller {
                        id: j,
                        attach: TileId(row * w + spread(j, n, w)),
                    });
                }
            }
            CtrlPlacement::Explicit(ts) => {
                let tiles = w * h;
                for (j, &t) in ts.iter().enumerate() {
                    if t.0 >= tiles {
                        return Err(FabricError::Incompatible {
                            what: format!("ctrl={}", self.label()),
                            why: format!("tile {} out of range on a {w}x{h} grid", t.0),
                        });
                    }
                    if ts[..j].contains(&t) {
                        return Err(FabricError::Incompatible {
                            what: format!("ctrl={}", self.label()),
                            why: format!("tile {} listed twice", t.0),
                        });
                    }
                    cs.push(Controller {
                        id: j as u32,
                        attach: t,
                    });
                }
            }
        }
        Ok(cs)
    }
}

// ---------------------------------------------------------------------------
// FabricSpec: the parseable description
// ---------------------------------------------------------------------------

/// An exact scale factor parsed from a decimal literal like `0.5`
/// (applied as `service * num / den`, flooring — so halving a 1-cycle
/// link yields a free express link; raise `base=` first for finer grades).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Factor {
    pub num: u64,
    pub den: u64,
    text: String,
}

impl Factor {
    pub fn parse(s: &str) -> Result<Factor, FabricError> {
        let (int, frac) = match s.split_once('.') {
            Some((i, f)) => (i, Some(f)),
            None => (s, None),
        };
        let digits = |p: &str| !p.is_empty() && p.bytes().all(|b| b.is_ascii_digit());
        let frac_ok = match frac {
            Some(f) => digits(f) && f.len() <= 6,
            None => true,
        };
        if !digits(int) || !frac_ok {
            return Err(bad(s, "want a decimal factor like 2, 0.5, or 1.25"));
        }
        let den = 10u64.pow(match frac {
            Some(f) => f.len() as u32,
            None => 0,
        });
        let out_of_range = || bad(s, "factor out of range");
        let int_v = int.parse::<u64>().map_err(|_| out_of_range())?;
        let frac_v = match frac {
            Some(f) => f.parse::<u64>().map_err(|_| out_of_range())?,
            None => 0,
        };
        let num = int_v
            .checked_mul(den)
            .and_then(|v| v.checked_add(frac_v))
            .ok_or_else(out_of_range)?;
        Ok(Factor {
            num,
            den,
            text: s.to_string(),
        })
    }

    pub fn label(&self) -> &str {
        &self.text
    }

    /// Apply to a service value (floored; saturating on absurd inputs).
    #[inline]
    pub fn scale(&self, service: u64) -> u64 {
        service.saturating_mul(self.num) / self.den
    }
}

/// A region of directed links a rule scales.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LinkRegion {
    /// The east/west links of every tile on mesh row `y` (an express row).
    Row(u32),
    /// The north/south links of every tile in mesh column `x`.
    Col(u32),
    /// All links leaving boundary tiles (wider edge links).
    Edge,
    /// Every link in one direction (per-direction asymmetry).
    Direction(Dir),
}

/// One region-scaling rule of a [`FabricSpec`], e.g. `express-row=3@0.5`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LinkRule {
    pub region: LinkRegion,
    pub factor: Factor,
}

impl LinkRule {
    fn label(&self) -> String {
        match &self.region {
            LinkRegion::Row(y) => format!("express-row={y}@{}", self.factor.label()),
            LinkRegion::Col(x) => format!("express-col={x}@{}", self.factor.label()),
            LinkRegion::Edge => format!("edge@{}", self.factor.label()),
            LinkRegion::Direction(d) => format!("dir={}@{}", d.letter(), self.factor.label()),
        }
    }
}

/// A compact, machine-independent fabric description: an optional leading
/// machine clause (a `--machine` spec, CLI convenience), an optional
/// controller placement, an optional uniform base service, and region
/// rules applied in order.
///
/// # Examples
///
/// The issue-style one-liner — grid, corner controllers, and a half-cost
/// express row — parses, labels back, and applies to a machine:
///
/// ```
/// use tilesim::arch::{CtrlPlacement, FabricSpec, MachineSpec};
///
/// let spec = FabricSpec::parse("8x8:ctrl=corners:express-row=3@0.5").unwrap();
/// let (machine, fabric) = spec.split_machine();
/// assert_eq!(machine, Some(MachineSpec::parse("8x8").unwrap()));
/// assert_eq!(fabric.ctrl, Some(CtrlPlacement::Corners));
/// assert_eq!(fabric.label(), "ctrl=corners:express-row=3@0.5");
///
/// // Applying rebuilds the controllers and the per-link service table.
/// let m = machine.unwrap().build().with_fabric(&fabric).unwrap();
/// assert_eq!(m.controllers()[0].attach.0, 0); // a corner, not an edge column
/// assert!(m.fabric().uniform_service().is_none());
///
/// // `base=` sets the uniform service the rules scale: 4 @ 0.5 = 2.
/// let f = FabricSpec::parse("base=4:express-row=0@0.5").unwrap();
/// let m = MachineSpec::parse("4x4").unwrap().build().with_fabric(&f).unwrap();
/// assert_eq!(m.fabric().classes(), vec![(2, 8), (4, 56)]);
///
/// // Malformed specs are rejected, not guessed at.
/// assert!(FabricSpec::parse("express-row=@2").is_err());
/// assert!(FabricSpec::parse("warp=9").is_err());
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct FabricSpec {
    /// Leading machine clause, if the spec carried one (stripped by
    /// [`split_machine`](Self::split_machine) before a `RunSpec` stores
    /// the fabric).
    pub machine: Option<MachineSpec>,
    /// Controller placement override.
    pub ctrl: Option<CtrlPlacement>,
    /// Uniform base service before rules (default: the machine's
    /// `link_service`).
    pub base: Option<u64>,
    /// Region rules, applied in order (stacking composes).
    pub rules: Vec<LinkRule>,
}

impl FabricSpec {
    /// Parse a `:`-separated clause list. Clauses:
    ///
    /// - a leading machine spec (`tilepro64`, `8x8`, `16x16:8`, …);
    /// - `ctrl=<placement>` (see [`CtrlPlacement::parse`]);
    /// - `base=N` — uniform service the rules scale;
    /// - `express-row=Y@F`, `express-col=X@F`, `edge@F`, `dir=D@F` with
    ///   `D` one of `E|W|N|S` and `F` a decimal factor.
    pub fn parse(s: &str) -> Result<FabricSpec, FabricError> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.iter().any(|p| p.is_empty()) {
            return Err(bad(s, "empty clause"));
        }
        let mut spec = FabricSpec::default();
        let mut i = 0;
        // A leading clause without '=' or '@' is a machine spec; a bare
        // numeric clause after it is the machine's `:ctrls` suffix.
        if let Some(first) = parts.first() {
            if !first.contains('=') && !first.contains('@') {
                let mut mstr = first.to_string();
                i = 1;
                if let Some(second) = parts.get(1) {
                    if second.bytes().all(|b| b.is_ascii_digit()) {
                        mstr = format!("{first}:{second}");
                        i = 2;
                    }
                }
                spec.machine = Some(
                    MachineSpec::parse(&mstr)
                        .map_err(|e| bad(s, format!("machine clause: {e}")))?,
                );
            }
        }
        for clause in &parts[i..] {
            if let Some(rest) = clause.strip_prefix("ctrl=") {
                if spec.ctrl.is_some() {
                    return Err(bad(s, "duplicate ctrl= clause"));
                }
                spec.ctrl = Some(CtrlPlacement::parse(rest)?);
            } else if let Some(rest) = clause.strip_prefix("base=") {
                if spec.base.is_some() {
                    return Err(bad(s, "duplicate base= clause"));
                }
                let b = rest
                    .parse::<u64>()
                    .map_err(|_| bad(s, format!("base '{rest}' is not an integer")))?;
                spec.base = Some(b);
            } else {
                spec.rules.push(FabricSpec::parse_rule(s, clause)?);
            }
        }
        if spec.machine.is_none()
            && spec.ctrl.is_none()
            && spec.base.is_none()
            && spec.rules.is_empty()
        {
            return Err(bad(s, "no clauses"));
        }
        Ok(spec)
    }

    fn parse_rule(spec: &str, clause: &str) -> Result<LinkRule, FabricError> {
        let (lhs, factor) = clause
            .split_once('@')
            .ok_or_else(|| bad(spec, format!("clause '{clause}' is not a known clause or rule")))?;
        let factor = Factor::parse(factor)?;
        let index = |rest: &str, what: &str| -> Result<u32, FabricError> {
            rest.parse::<u32>()
                .map_err(|_| bad(spec, format!("{what} '{rest}' is not an integer")))
        };
        let region = if let Some(rest) = lhs.strip_prefix("express-row=") {
            LinkRegion::Row(index(rest, "express-row")?)
        } else if let Some(rest) = lhs.strip_prefix("express-col=") {
            LinkRegion::Col(index(rest, "express-col")?)
        } else if lhs == "edge" {
            LinkRegion::Edge
        } else if let Some(rest) = lhs.strip_prefix("dir=") {
            let dir = match rest {
                "E" => Dir::East,
                "W" => Dir::West,
                "N" => Dir::North,
                "S" => Dir::South,
                _ => return Err(bad(spec, format!("dir '{rest}': want E|W|N|S"))),
            };
            LinkRegion::Direction(dir)
        } else {
            return Err(bad(spec, format!("unknown rule '{lhs}'")));
        };
        Ok(LinkRule { region, factor })
    }

    /// Canonical label: machine clause (if any), then `ctrl=`, `base=`,
    /// rules in order. `parse(label())` round-trips.
    pub fn label(&self) -> String {
        let mut clauses: Vec<String> = Vec::new();
        if let Some(m) = self.machine {
            clauses.push(m.label());
        }
        if let Some(p) = &self.ctrl {
            clauses.push(format!("ctrl={}", p.label()));
        }
        if let Some(b) = self.base {
            clauses.push(format!("base={b}"));
        }
        for r in &self.rules {
            clauses.push(r.label());
        }
        clauses.join(":")
    }

    /// Split off the leading machine clause (CLI normalisation: the
    /// machine goes to `--machine` handling, the rest rides in the
    /// `RunSpec`).
    pub fn split_machine(mut self) -> (Option<MachineSpec>, FabricSpec) {
        let m = self.machine.take();
        (m, self)
    }

    /// Whether applying this spec changes nothing (no placement, no base,
    /// no rules).
    pub fn is_noop(&self) -> bool {
        self.ctrl.is_none() && self.base.is_none() && self.rules.is_empty()
    }

    /// Build the per-link service table for `machine`. Region indices are
    /// validated against the machine's grid.
    pub fn build_table(&self, machine: &Machine) -> Result<Fabric, FabricError> {
        let base = self.base.unwrap_or(machine.params.link_service);
        let n = machine.num_tiles() as usize;
        let mut service = vec![base; machine.num_links()];
        for rule in &self.rules {
            match rule.region {
                LinkRegion::Row(y) => {
                    if y >= machine.grid_h() {
                        return Err(FabricError::Incompatible {
                            what: rule.label(),
                            why: format!("row {y} on a {} -row grid", machine.grid_h()),
                        });
                    }
                    for x in 0..machine.grid_w() {
                        let t = TileId(y * machine.grid_w() + x);
                        for dir in [Dir::East, Dir::West] {
                            let ix = machine.link_index(t, dir);
                            service[ix] = rule.factor.scale(service[ix]);
                        }
                    }
                }
                LinkRegion::Col(x) => {
                    if x >= machine.grid_w() {
                        return Err(FabricError::Incompatible {
                            what: rule.label(),
                            why: format!("column {x} on a {} -wide grid", machine.grid_w()),
                        });
                    }
                    for y in 0..machine.grid_h() {
                        let t = TileId(y * machine.grid_w() + x);
                        for dir in [Dir::North, Dir::South] {
                            let ix = machine.link_index(t, dir);
                            service[ix] = rule.factor.scale(service[ix]);
                        }
                    }
                }
                LinkRegion::Edge => {
                    for t in machine.tiles() {
                        let c = machine.coord(t);
                        let on_edge = c.x == 0
                            || c.y == 0
                            || c.x == machine.grid_w() - 1
                            || c.y == machine.grid_h() - 1;
                        if on_edge {
                            for dir in Dir::ALL {
                                let ix = machine.link_index(t, dir);
                                service[ix] = rule.factor.scale(service[ix]);
                            }
                        }
                    }
                }
                LinkRegion::Direction(d) => {
                    for ix in d.index() * n..(d.index() + 1) * n {
                        service[ix] = rule.factor.scale(service[ix]);
                    }
                }
            }
        }
        Ok(Fabric::from_services(service))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Coord;

    #[test]
    fn uniform_fabric_reports_its_service() {
        let f = Fabric::uniform(16, 3);
        assert_eq!(f.uniform_service(), Some(3));
        assert_eq!(f.classes(), vec![(3, 16)]);
        assert_eq!(f.service(7), 3);
        let het = Fabric::from_services(vec![1, 1, 2, 4]);
        assert_eq!(het.uniform_service(), None);
        assert_eq!(het.classes(), vec![(1, 2), (2, 1), (4, 1)]);
    }

    #[test]
    fn factor_parses_exact_rationals() {
        assert_eq!(Factor::parse("0.5").unwrap().scale(4), 2);
        assert_eq!(Factor::parse("0.25").unwrap().scale(4), 1);
        assert_eq!(Factor::parse("2").unwrap().scale(3), 6);
        assert_eq!(Factor::parse("1.25").unwrap().scale(8), 10);
        // Flooring: halving a 1-cycle link is a free express link.
        assert_eq!(Factor::parse("0.5").unwrap().scale(1), 0);
        for s in ["", ".", "1.", ".5", "a", "1.x", "0.1234567", "-1"] {
            assert!(Factor::parse(s).is_err(), "factor '{s}' should fail");
        }
    }

    #[test]
    fn placement_parse_round_trips() {
        for p in [
            CtrlPlacement::EdgesEven,
            CtrlPlacement::Sides,
            CtrlPlacement::Corners,
            CtrlPlacement::Interior,
            CtrlPlacement::Explicit(vec![TileId(0), TileId(27), TileId(63)]),
        ] {
            assert_eq!(CtrlPlacement::parse(&p.label()).unwrap(), p);
        }
        assert!(CtrlPlacement::parse("middle").is_err());
        assert!(CtrlPlacement::parse("").is_err());
        assert!(CtrlPlacement::parse("1+x").is_err());
    }

    #[test]
    fn edges_even_matches_pre_fabric_columns() {
        // The seed's 8x8/4 pattern: columns 2 and 5 on rows 0 and 7.
        let cs = CtrlPlacement::EdgesEven.controllers(8, 8, 4).unwrap();
        let attaches: Vec<u32> = cs.iter().map(|c| c.attach.0).collect();
        assert_eq!(attaches, vec![2, 5, 7 * 8 + 2, 7 * 8 + 5]);
        assert_eq!(cs.iter().map(|c| c.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn sides_attach_to_left_and_right_edges() {
        let cs = CtrlPlacement::Sides.controllers(8, 8, 4).unwrap();
        for c in &cs {
            let x = c.attach.0 % 8;
            assert!(x == 0 || x == 7, "{c:?} not on a side edge");
        }
        let attaches: std::collections::HashSet<_> = cs.iter().map(|c| c.attach).collect();
        assert_eq!(attaches.len(), 4, "distinct attach tiles");
    }

    #[test]
    fn corners_spread_opposite_first() {
        let cs = CtrlPlacement::Corners.controllers(8, 8, 2).unwrap();
        assert_eq!(cs[0].attach, TileId(0));
        assert_eq!(cs[1].attach, TileId(63));
        assert!(CtrlPlacement::Corners.controllers(8, 8, 4).is_ok());
        assert!(CtrlPlacement::Corners.controllers(8, 8, 5).is_err());
        // A single-row grid has only two distinct corners.
        assert_eq!(CtrlPlacement::Corners.capacity(4, 1), 2);
        assert_eq!(CtrlPlacement::Corners.capacity(1, 1), 1);
    }

    #[test]
    fn interior_sits_on_the_middle_row() {
        let cs = CtrlPlacement::Interior.controllers(8, 8, 4).unwrap();
        for c in &cs {
            assert_eq!(c.attach.0 / 8, 4, "{c:?} not on row h/2");
        }
        let cols: std::collections::HashSet<_> = cs.iter().map(|c| c.attach.0 % 8).collect();
        assert_eq!(cols.len(), 4);
    }

    #[test]
    fn explicit_placement_validates() {
        let p = CtrlPlacement::Explicit(vec![TileId(3), TileId(12)]);
        let cs = p.controllers(4, 4, 99).unwrap(); // count comes from the list
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[1], Controller { id: 1, attach: TileId(12) });
        assert!(CtrlPlacement::Explicit(vec![TileId(16)])
            .controllers(4, 4, 1)
            .is_err());
        assert!(CtrlPlacement::Explicit(vec![TileId(1), TileId(1)])
            .controllers(4, 4, 2)
            .is_err());
    }

    #[test]
    fn placement_capacity_rejects_overflow() {
        for p in [
            CtrlPlacement::EdgesEven,
            CtrlPlacement::Sides,
            CtrlPlacement::Corners,
            CtrlPlacement::Interior,
        ] {
            let cap = p.capacity(4, 4);
            assert!(p.controllers(4, 4, cap).is_ok(), "{p:?} at capacity");
            assert!(p.controllers(4, 4, cap + 1).is_err(), "{p:?} over capacity");
            assert!(p.controllers(4, 4, 0).is_err(), "{p:?} zero controllers");
            // All attach tiles distinct at capacity.
            let cs = p.controllers(4, 4, cap).unwrap();
            let distinct: std::collections::HashSet<_> =
                cs.iter().map(|c| c.attach).collect();
            assert_eq!(distinct.len(), cap as usize, "{p:?} stacked controllers");
        }
    }

    #[test]
    fn spec_parse_round_trips() {
        for s in [
            "ctrl=corners",
            "base=4",
            "express-row=3@0.5",
            "express-col=0@2",
            "edge@0.5",
            "dir=E@1.25",
            "ctrl=sides:base=8:express-row=1@0.5:dir=W@2",
            "8x8:4:ctrl=corners:express-row=3@0.5",
            "16x16:8:ctrl=interior",
            "epiphany16:dir=E@0.5",
        ] {
            let spec = FabricSpec::parse(s).unwrap();
            assert_eq!(spec.label(), s, "label must be the parser's inverse");
            assert_eq!(FabricSpec::parse(&spec.label()).unwrap(), spec);
        }
        // A machine clause without the `:ctrls` suffix canonicalises to
        // the full `WxH:ctrls` label but parses to the same spec.
        let spec = FabricSpec::parse("8x8:ctrl=corners:express-row=3@0.5").unwrap();
        assert_eq!(spec.label(), "8x8:4:ctrl=corners:express-row=3@0.5");
        assert_eq!(FabricSpec::parse(&spec.label()).unwrap(), spec);
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for s in [
            "",
            ":",
            "ctrl=",
            "ctrl=weird",
            "base=x",
            "base=4:base=5",
            "ctrl=edges:ctrl=sides",
            "express-row=@2",
            "express-row=3",
            "express-row=3@",
            "express-row=x@2",
            "dir=Q@2",
            "edge=2",
            "warp=9",
            "8x8:ctrl=corners:",
            "65x65:ctrl=corners",
        ] {
            assert!(FabricSpec::parse(s).is_err(), "spec '{s}' should fail");
        }
    }

    #[test]
    fn machine_clause_splits_off() {
        let (m, f) = FabricSpec::parse("16x16:8:ctrl=corners")
            .unwrap()
            .split_machine();
        assert_eq!(m, Some(MachineSpec::Custom { w: 16, h: 16, ctrls: 8 }));
        assert_eq!(f.machine, None);
        assert_eq!(f.label(), "ctrl=corners");
        // A bare machine clause is a valid (no-op) fabric.
        let (m, f) = FabricSpec::parse("epiphany16").unwrap().split_machine();
        assert_eq!(m, Some(MachineSpec::Epiphany16));
        assert!(f.is_noop());
    }

    #[test]
    fn table_rules_compose_in_order() {
        let m = MachineSpec::parse("4x4").unwrap().build();
        let f = FabricSpec::parse("base=8:express-row=0@0.5:dir=E@0.5")
            .unwrap()
            .build_table(&m)
            .unwrap();
        // Row 0 east links: 8 * 0.5 * 0.5 = 2; row 0 west: 4; other east: 4;
        // everything else: 8.
        assert_eq!(f.service(m.link_index(TileId(0), Dir::East)), 2);
        assert_eq!(f.service(m.link_index(TileId(0), Dir::West)), 4);
        assert_eq!(f.service(m.link_index(TileId(4), Dir::East)), 4);
        assert_eq!(f.service(m.link_index(TileId(4), Dir::North)), 8);
    }

    #[test]
    fn table_edge_region_covers_boundary_only() {
        let m = MachineSpec::parse("4x4").unwrap().build();
        let f = FabricSpec::parse("base=2:edge@2")
            .unwrap()
            .build_table(&m)
            .unwrap();
        for t in m.tiles() {
            let Coord { x, y } = m.coord(t);
            let expect = if x == 0 || y == 0 || x == 3 || y == 3 { 4 } else { 2 };
            for dir in Dir::ALL {
                assert_eq!(f.service(m.link_index(t, dir)), expect, "tile {t:?} {dir:?}");
            }
        }
    }

    #[test]
    fn table_rejects_out_of_range_regions() {
        let m = MachineSpec::parse("4x4").unwrap().build();
        assert!(FabricSpec::parse("express-row=4@0.5")
            .unwrap()
            .build_table(&m)
            .is_err());
        assert!(FabricSpec::parse("express-col=9@0.5")
            .unwrap()
            .build_table(&m)
            .is_err());
    }

    #[test]
    fn default_base_is_the_machine_link_service() {
        let m = Machine::tilepro64();
        let f = FabricSpec::parse("dir=E@1").unwrap().build_table(&m).unwrap();
        assert_eq!(f.uniform_service(), Some(m.params.link_service));
    }
}
