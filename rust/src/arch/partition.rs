//! Spatial partitioning: carve a [`Machine`] into disjoint rectangular
//! sub-grids that serve concurrent requests without sharing anything.
//!
//! The paper's localisation argument is that speed-up comes from keeping a
//! computation's pages homed on nearby tiles. A [`Partition`] is the
//! serving-layer expression of that: each in-flight batch replays on its
//! own rectangle, and because homing, page table, and directory are
//! constructed over the partition's *view* (a [`Machine`] with the
//! partition's dimensions and its own controller set), every page of a
//! request homes inside the partition's tiles **by construction** — there
//! is no cross-request directory sharing or link interference to model
//! away, the address spaces simply never meet.
//!
//! Two geometric facts make the local-coordinate replay exact in global
//! coordinates:
//!
//! 1. **Rectangles are XY-closed.** XY dimension-order routing between two
//!    tiles of an axis-aligned rectangle only visits tiles whose x lies
//!    between the endpoints' x and whose y lies between the endpoints' y —
//!    all inside the rectangle. No route of a partition-confined replay
//!    ever leaves the partition.
//! 2. **XY routing is translation-invariant.** Shifting both endpoints by
//!    `(x0, y0)` shifts every tile of the route by `(x0, y0)`. So a link
//!    billed at local `(x, y, dir)` is exactly the parent link at
//!    `(x + x0, y + y0, dir)` — [`Partition::global_link_index`] is that
//!    translation, and per-partition link maps compose onto the parent
//!    grid without double counting (partitions are disjoint).
//!
//! A corollary the serve dispatcher leans on: the view is a pure function
//! of the partition's *shape* (dims + the parent's parameter set), not its
//! position, so two same-shaped partitions have identical service times
//! and replays memoise per (shape, batch size) — a P-way ladder costs at
//! most `distinct_shapes x max_batch` engine replays.

use super::machine::{Machine, MachineError};
use super::topology::{Coord, Dir, TileId};

/// An axis-aligned tile rectangle in parent-grid coordinates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rect {
    pub x0: u32,
    pub y0: u32,
    pub w: u32,
    pub h: u32,
}

impl Rect {
    fn contains(&self, c: Coord) -> bool {
        c.x >= self.x0 && c.x < self.x0 + self.w && c.y >= self.y0 && c.y < self.y0 + self.h
    }

    fn overlaps(&self, o: &Rect) -> bool {
        self.x0 < o.x0 + o.w && o.x0 < self.x0 + self.w && self.y0 < o.y0 + o.h
            && o.y0 < self.y0 + self.h
    }

    fn label(&self) -> String {
        format!("{},{},{}x{}", self.x0, self.y0, self.w, self.h)
    }
}

/// How to carve a machine into partitions (`--partitions`).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum PartitionSpec {
    /// One partition covering the whole chip — the single-server baseline.
    #[default]
    Whole,
    /// `N` partitions in the axis-aligned grid of N cells closest to
    /// square that divides the machine (`--partitions 4` on 8x8 = `2x2`).
    Auto(u32),
    /// `PXxPY` cells: PX columns of partitions by PY rows.
    Grid { px: u32, py: u32 },
    /// `rowsN`: N full-width horizontal bands.
    Rows(u32),
    /// `colsN`: N full-height vertical bands.
    Cols(u32),
    /// `explicit:x,y,WxH;...` — hand-placed disjoint rectangles (need not
    /// cover the chip; uncovered tiles simply serve nothing).
    Explicit(Vec<Rect>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PartitionError {
    BadSpec(String),
    /// The spec does not divide the machine's grid evenly.
    DoesNotDivide { spec: String, w: u32, h: u32 },
    /// An explicit rectangle leaves the grid or has zero area.
    OutOfBounds { rect: String, w: u32, h: u32 },
    /// Two explicit rectangles share a tile.
    Overlap { a: String, b: String },
    /// The carved sub-grid is not a valid machine (e.g. zero tiles).
    BadView(MachineError),
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::BadSpec(s) => write!(
                f,
                "bad partition spec '{s}' (want whole | N | PXxPY | rowsN | colsN | \
                 explicit:x,y,WxH;...)"
            ),
            PartitionError::DoesNotDivide { spec, w, h } => {
                write!(f, "partition spec '{spec}' does not divide a {w}x{h} grid evenly")
            }
            PartitionError::OutOfBounds { rect, w, h } => {
                write!(f, "partition rect '{rect}' leaves the {w}x{h} grid (or is empty)")
            }
            PartitionError::Overlap { a, b } => {
                write!(f, "partition rects '{a}' and '{b}' overlap: partitions must be disjoint")
            }
            PartitionError::BadView(e) => write!(f, "partition view: {e}"),
        }
    }
}

impl std::error::Error for PartitionError {}

impl PartitionSpec {
    /// Parse a `--partitions` argument. Labels round-trip:
    ///
    /// ```
    /// use tilesim::arch::PartitionSpec;
    ///
    /// for s in ["whole", "4", "2x2", "rows4", "cols2", "explicit:0,0,4x4;4,0,4x4"] {
    ///     assert_eq!(PartitionSpec::parse(s).unwrap().label(), s);
    /// }
    /// ```
    pub fn parse(s: &str) -> Result<PartitionSpec, PartitionError> {
        let err = || PartitionError::BadSpec(s.to_string());
        if s == "whole" {
            return Ok(PartitionSpec::Whole);
        }
        if let Some(n) = s.strip_prefix("rows") {
            let n = n.parse::<u32>().map_err(|_| err())?;
            return if n >= 1 { Ok(PartitionSpec::Rows(n)) } else { Err(err()) };
        }
        if let Some(n) = s.strip_prefix("cols") {
            let n = n.parse::<u32>().map_err(|_| err())?;
            return if n >= 1 { Ok(PartitionSpec::Cols(n)) } else { Err(err()) };
        }
        if let Some(rects) = s.strip_prefix("explicit:") {
            let rects = rects
                .split(';')
                .map(|r| {
                    // x,y,WxH
                    let mut parts = r.splitn(3, ',');
                    let x0 = parts.next().and_then(|v| v.parse().ok())?;
                    let y0 = parts.next().and_then(|v| v.parse().ok())?;
                    let (w, h) = parts.next()?.split_once('x')?;
                    let (w, h) = (w.parse().ok()?, h.parse().ok()?);
                    Some(Rect { x0, y0, w, h })
                })
                .collect::<Option<Vec<_>>>()
                .ok_or_else(err)?;
            return if rects.is_empty() { Err(err()) } else { Ok(PartitionSpec::Explicit(rects)) };
        }
        if let Some((px, py)) = s.split_once('x') {
            let (px, py) = (
                px.parse::<u32>().map_err(|_| err())?,
                py.parse::<u32>().map_err(|_| err())?,
            );
            return if px >= 1 && py >= 1 {
                Ok(PartitionSpec::Grid { px, py })
            } else {
                Err(err())
            };
        }
        match s.parse::<u32>() {
            Ok(n) if n >= 1 => Ok(PartitionSpec::Auto(n)),
            _ => Err(err()),
        }
    }

    /// Stable label (round-trips through [`parse`](Self::parse)).
    pub fn label(&self) -> String {
        match self {
            PartitionSpec::Whole => "whole".into(),
            PartitionSpec::Auto(n) => format!("{n}"),
            PartitionSpec::Grid { px, py } => format!("{px}x{py}"),
            PartitionSpec::Rows(n) => format!("rows{n}"),
            PartitionSpec::Cols(n) => format!("cols{n}"),
            PartitionSpec::Explicit(rects) => format!(
                "explicit:{}",
                rects.iter().map(Rect::label).collect::<Vec<_>>().join(";")
            ),
        }
    }

    /// Whether this spec carves exactly one partition covering the whole
    /// chip of *any* machine — the configurations whose serve records must
    /// stay byte-identical to the single-server driver's.
    pub fn is_whole(&self) -> bool {
        matches!(
            self,
            PartitionSpec::Whole
                | PartitionSpec::Auto(1)
                | PartitionSpec::Grid { px: 1, py: 1 }
                | PartitionSpec::Rows(1)
                | PartitionSpec::Cols(1)
        )
    }

    /// Carve `machine` into disjoint partitions, indexed row-major over
    /// the carving grid (explicit rects keep their written order). Every
    /// grid-style spec must divide the machine evenly.
    pub fn carve(&self, machine: &Machine) -> Result<Vec<Partition>, PartitionError> {
        let (w, h) = (machine.grid_w(), machine.grid_h());
        let grid = |px: u32, py: u32| -> Result<Vec<Partition>, PartitionError> {
            if px == 0 || py == 0 || w % px != 0 || h % py != 0 {
                return Err(PartitionError::DoesNotDivide { spec: self.label(), w, h });
            }
            let (pw, ph) = (w / px, h / py);
            Ok((0..py)
                .flat_map(|cy| (0..px).map(move |cx| (cx, cy)))
                .enumerate()
                .map(|(index, (cx, cy))| Partition {
                    index,
                    rect: Rect { x0: cx * pw, y0: cy * ph, w: pw, h: ph },
                })
                .collect())
        };
        match self {
            PartitionSpec::Whole => grid(1, 1),
            PartitionSpec::Grid { px, py } => grid(*px, *py),
            PartitionSpec::Rows(n) => grid(1, *n),
            PartitionSpec::Cols(n) => grid(*n, 1),
            PartitionSpec::Auto(n) => {
                // Squarest ordered factorisation (px, py) of n that divides
                // the grid: minimise the cell aspect gap |w/px - h/py|,
                // tie-break on more columns — fully deterministic.
                let mut best: Option<(u32, u32)> = None;
                for px in 1..=*n {
                    if n % px != 0 {
                        continue;
                    }
                    let py = n / px;
                    if w % px != 0 || h % py != 0 {
                        continue;
                    }
                    let gap = (w / px).abs_diff(h / py);
                    if best
                        .map(|(bx, by)| {
                            let bgap = (w / bx).abs_diff(h / by);
                            (gap, u32::MAX - px) < (bgap, u32::MAX - bx)
                        })
                        .unwrap_or(true)
                    {
                        best = Some((px, py));
                    }
                }
                let (px, py) = best
                    .ok_or(PartitionError::DoesNotDivide { spec: self.label(), w, h })?;
                grid(px, py)
            }
            PartitionSpec::Explicit(rects) => {
                for r in rects {
                    if r.w == 0
                        || r.h == 0
                        || r.x0 + r.w > w
                        || r.y0 + r.h > h
                    {
                        return Err(PartitionError::OutOfBounds { rect: r.label(), w, h });
                    }
                }
                for (i, a) in rects.iter().enumerate() {
                    for b in &rects[i + 1..] {
                        if a.overlaps(b) {
                            return Err(PartitionError::Overlap {
                                a: a.label(),
                                b: b.label(),
                            });
                        }
                    }
                }
                Ok(rects
                    .iter()
                    .enumerate()
                    .map(|(index, &rect)| Partition { index, rect })
                    .collect())
            }
        }
    }
}

/// One carved sub-grid of a parent machine: a server of the spatial
/// multi-server dispatcher. Coordinates are parent-grid; the replay view
/// ([`Partition::view`]) is local (its tile 0 is this rect's corner).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Dispatch index (the deterministic round-robin/tie-break key).
    pub index: usize,
    pub rect: Rect,
}

impl Partition {
    #[inline]
    pub fn width(&self) -> u32 {
        self.rect.w
    }

    #[inline]
    pub fn height(&self) -> u32 {
        self.rect.h
    }

    #[inline]
    pub fn num_tiles(&self) -> u32 {
        self.rect.w * self.rect.h
    }

    /// The memoisation key: same-shaped partitions of the same parent have
    /// identical views, hence identical service times.
    #[inline]
    pub fn shape(&self) -> (u32, u32) {
        (self.rect.w, self.rect.h)
    }

    /// Server label for reports, e.g. `p0:4x4@0,0`.
    pub fn label(&self) -> String {
        format!(
            "p{}:{}x{}@{},{}",
            self.index, self.rect.w, self.rect.h, self.rect.x0, self.rect.y0
        )
    }

    /// Whether a parent-grid tile lies inside this partition.
    pub fn contains(&self, parent: &Machine, t: TileId) -> bool {
        self.rect.contains(parent.coord(t))
    }

    /// Translate a view-local tile to the parent-grid tile it models.
    #[inline]
    pub fn global_tile(&self, parent: &Machine, local: TileId) -> TileId {
        let x = local.0 % self.rect.w;
        let y = local.0 / self.rect.w;
        parent.tile_at(Coord { x: x + self.rect.x0, y: y + self.rect.y0 })
    }

    /// Parent-grid tiles of this partition, in view-local id order.
    pub fn tiles<'a>(&'a self, parent: &'a Machine) -> impl Iterator<Item = TileId> + 'a {
        (0..self.num_tiles()).map(move |i| self.global_tile(parent, TileId(i)))
    }

    /// Translate a view-local directed-link index to the parent-grid link
    /// it models — the XY translation-invariance of the module docs made
    /// concrete. Composing per-partition link maps through this is exact:
    /// disjoint partitions never map onto the same parent link.
    pub fn global_link_index(&self, parent: &Machine, local_index: usize) -> usize {
        let n = self.num_tiles() as usize;
        let dir = Dir::ALL[local_index / n];
        let local = TileId((local_index % n) as u32);
        parent.link_index(self.global_tile(parent, local), dir)
    }

    /// The partition's replay view: a [`Machine`] with this rect's
    /// dimensions, the parent's latency/geometry/clock, a proportional
    /// share of the parent's controllers (its own homing/memory domain),
    /// and a uniform fabric at the parent's scalar link service. A
    /// whole-chip partition's view *is* the parent (clone), so `P = 1`
    /// collapses to the single-server driver exactly.
    pub fn view(&self, parent: &Machine) -> Result<Machine, PartitionError> {
        parent
            .subgrid_view(self.rect.w, self.rect.h)
            .map_err(PartitionError::BadView)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m8x8() -> Machine {
        Machine::tilepro64()
    }

    #[test]
    fn spec_parse_label_round_trips() {
        for s in [
            "whole",
            "2",
            "4",
            "2x2",
            "4x1",
            "rows4",
            "cols2",
            "explicit:0,0,4x4;4,0,4x4",
            "explicit:1,2,3x4",
        ] {
            let spec = PartitionSpec::parse(s).unwrap();
            assert_eq!(spec.label(), s);
            assert_eq!(PartitionSpec::parse(&spec.label()).unwrap(), spec);
        }
        for s in [
            "", "0", "rows0", "cols", "2x0", "0x2", "axb", "explicit:", "explicit:0,0",
            "explicit:0,0,4", "explicit:0,0,4x", "wholes",
        ] {
            assert!(PartitionSpec::parse(s).is_err(), "'{s}' must not parse");
        }
    }

    #[test]
    fn whole_like_specs_are_detected() {
        for s in ["whole", "1", "1x1", "rows1", "cols1"] {
            assert!(PartitionSpec::parse(s).unwrap().is_whole(), "{s}");
        }
        for s in ["2", "2x1", "rows2", "explicit:0,0,8x8"] {
            assert!(!PartitionSpec::parse(s).unwrap().is_whole(), "{s}");
        }
    }

    #[test]
    fn grid_carve_covers_disjointly() {
        let m = m8x8();
        for spec in ["2x2", "4", "rows4", "cols2", "8", "4x2"] {
            let parts = PartitionSpec::parse(spec).unwrap().carve(&m).unwrap();
            let mut seen = std::collections::HashSet::new();
            for p in &parts {
                for t in p.tiles(&m) {
                    assert!(seen.insert(t), "{spec}: tile {t:?} in two partitions");
                    assert!(p.contains(&m, t));
                }
            }
            assert_eq!(seen.len(), 64, "{spec}: grid carves must cover the chip");
            // Indices are dense and ordered.
            for (i, p) in parts.iter().enumerate() {
                assert_eq!(p.index, i);
            }
        }
    }

    #[test]
    fn auto_picks_the_squarest_division() {
        let m = m8x8();
        let parts = PartitionSpec::Auto(4).carve(&m).unwrap();
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].shape(), (4, 4), "4 on 8x8 must carve 2x2 quadrants");
        let parts = PartitionSpec::Auto(2).carve(&m).unwrap();
        assert_eq!(parts[0].shape(), (4, 8), "2 on 8x8 splits columns first");
        // A grid the count cannot divide is an error, not a silent remainder.
        let m5 = Machine::custom(5, 7, 2).unwrap();
        assert!(PartitionSpec::Auto(4).carve(&m5).is_err());
        assert!(PartitionSpec::parse("3x3").unwrap().carve(&m).is_err());
    }

    #[test]
    fn explicit_rects_validate_bounds_and_overlap() {
        let m = m8x8();
        let ok = PartitionSpec::parse("explicit:0,0,4x8;4,0,4x4").unwrap();
        let parts = ok.carve(&m).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].rect, Rect { x0: 4, y0: 0, w: 4, h: 4 });
        assert!(matches!(
            PartitionSpec::parse("explicit:6,0,4x4").unwrap().carve(&m),
            Err(PartitionError::OutOfBounds { .. })
        ));
        assert!(matches!(
            PartitionSpec::parse("explicit:0,0,4x4;3,3,2x2").unwrap().carve(&m),
            Err(PartitionError::Overlap { .. })
        ));
    }

    #[test]
    fn tile_translation_round_trips() {
        let m = m8x8();
        let parts = PartitionSpec::parse("2x2").unwrap().carve(&m).unwrap();
        let p = &parts[3]; // bottom-right quadrant
        assert_eq!(p.rect, Rect { x0: 4, y0: 4, w: 4, h: 4 });
        // Local tile 0 is the rect corner; local row-major order holds.
        assert_eq!(p.global_tile(&m, TileId(0)), m.tile_at(Coord { x: 4, y: 4 }));
        assert_eq!(p.global_tile(&m, TileId(5)), m.tile_at(Coord { x: 5, y: 5 }));
        let view = p.view(&m).unwrap();
        for local in view.tiles() {
            let g = p.global_tile(&m, local);
            assert!(p.contains(&m, g));
            // Coordinates translate by the rect origin.
            let lc = view.coord(local);
            let gc = m.coord(g);
            assert_eq!((gc.x, gc.y), (lc.x + 4, lc.y + 4));
        }
    }

    #[test]
    fn link_translation_preserves_direction_and_stays_inside() {
        let m = m8x8();
        let parts = PartitionSpec::parse("4").unwrap().carve(&m).unwrap();
        for p in &parts {
            let view = p.view(&m).unwrap();
            let mut seen = std::collections::HashSet::new();
            for local in view.tiles() {
                for dir in Dir::ALL {
                    let ix = p.global_link_index(&m, view.link_index(local, dir));
                    assert_eq!(ix, m.link_index(p.global_tile(&m, local), dir));
                    assert!(seen.insert(ix), "local links map to distinct parent links");
                }
            }
        }
    }

    #[test]
    fn xy_routes_inside_a_rect_translate_exactly() {
        // The invariance the global-coordinate billing story rests on:
        // route the view, route the parent between the translated
        // endpoints — same links modulo translation.
        use crate::noc::routing::xy_path;
        let m = m8x8();
        let parts = PartitionSpec::parse("2x2").unwrap().carve(&m).unwrap();
        let p = &parts[2];
        let view = p.view(&m).unwrap();
        for a in view.tiles() {
            for b in [TileId(0), TileId(5), TileId(15)] {
                let local: Vec<TileId> = xy_path(&view, a, b);
                let global: Vec<TileId> =
                    xy_path(&m, p.global_tile(&m, a), p.global_tile(&m, b));
                assert_eq!(local.len(), global.len());
                for (l, g) in local.iter().zip(&global) {
                    assert_eq!(p.global_tile(&m, *l), *g);
                    assert!(p.contains(&m, *g), "XY route left the rectangle");
                }
            }
        }
    }

    #[test]
    fn whole_partition_view_is_the_parent() {
        let m = m8x8();
        let parts = PartitionSpec::Whole.carve(&m).unwrap();
        assert_eq!(parts.len(), 1);
        let v = parts[0].view(&m).unwrap();
        assert_eq!(v.spec(), m.spec());
        assert_eq!(v.controllers(), m.controllers());
        assert_eq!(v.params.clock_hz, m.params.clock_hz);
    }

    #[test]
    fn views_inherit_parent_params_and_scale_controllers() {
        let m = Machine::nuca256(); // non-TILEPro params: inheritance visible
        let parts = PartitionSpec::parse("2x2").unwrap().carve(&m).unwrap();
        for p in &parts {
            let v = p.view(&m).unwrap();
            assert_eq!((v.grid_w(), v.grid_h()), (8, 8));
            // nuca256 params, not the Custom-machine TILEPro defaults.
            assert_eq!(v.params.clock_hz, m.params.clock_hz);
            assert_eq!(v.params.ddr, m.params.ddr);
            // 8 controllers over 4 equal partitions: 2 each.
            assert_eq!(v.num_controllers(), 2);
            for c in v.controllers() {
                assert!(c.attach.0 < v.num_tiles());
            }
        }
    }

    #[test]
    fn same_shape_means_same_view() {
        // The memoisation contract: shape determines the view.
        let m = m8x8();
        let parts = PartitionSpec::parse("2x2").unwrap().carve(&m).unwrap();
        let a = parts[0].view(&m).unwrap();
        let b = parts[3].view(&m).unwrap();
        assert_eq!(a.controllers(), b.controllers());
        assert_eq!((a.grid_w(), a.grid_h()), (b.grid_w(), b.grid_h()));
    }
}
