//! Mesh interconnect: XY routing (paths and directed-link walks) and
//! shared-resource queueing contention (home ports, controllers, links).

pub mod contention;
pub mod routing;

pub use contention::{ContentionConfig, ContentionModel};
pub use routing::{xy_links, xy_path, LinkHop, XyLinks};
