//! Mesh interconnect: XY routing and shared-resource queueing contention.

pub mod contention;
pub mod routing;

pub use contention::{ContentionConfig, ContentionModel};
pub use routing::xy_path;
