//! Mesh interconnect: XY routing (paths and directed-link walks) and
//! shared-resource queueing contention (home ports, controllers, links),
//! with link traffic billed by class — forward requests, wormhole-piped
//! replies, and coherence-invalidation fan-out + acks.

pub mod contention;
pub mod routing;

pub use contention::{ContentionConfig, ContentionModel};
pub use routing::{xy_links, xy_path, LinkHop, XyLinks};
