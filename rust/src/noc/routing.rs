//! XY dimension-order routing over the machine's mesh.
//!
//! Latency uses the hop count (`Machine::hops`). The explicit tile path
//! ([`xy_path`]) is used by tests; every billed traversal — forward
//! requests, data/ack replies, and invalidation fan-out with its acks —
//! walks the same route through the allocation-free directed-link
//! iterator ([`xy_links`]), which feeds the per-link servers of the
//! contention model (`noc::contention`).

use crate::arch::{Coord, Dir, Machine, TileId};

/// Tiles traversed from `src` to `dst` under XY routing (X first, then Y),
/// inclusive of both endpoints.
///
/// **Test-only support API.** This allocates a `Vec` per call and sits on
/// no production path: every billed traversal in the engine and the
/// contention model walks [`xy_links`] instead (allocation-free, and the
/// two are pinned to agree by `integration_noc`/`prop_invariants`). It is
/// not `#[cfg(test)]` only because the integration-test crates link
/// against the library build. New engine code should never call it.
pub fn xy_path(machine: &Machine, src: TileId, dst: TileId) -> Vec<TileId> {
    let a = machine.coord(src);
    let b = machine.coord(dst);
    let mut path = Vec::with_capacity((a.x.abs_diff(b.x) + a.y.abs_diff(b.y) + 1) as usize);
    let mut x = a.x;
    let y = a.y;
    path.push(src);
    while x != b.x {
        if x < b.x {
            x += 1;
        } else {
            x -= 1;
        }
        path.push(machine.tile_at(Coord { x, y }));
    }
    let mut y = a.y;
    while y != b.y {
        if y < b.y {
            y += 1;
        } else {
            y -= 1;
        }
        path.push(machine.tile_at(Coord { x: b.x, y }));
    }
    path
}

/// One directed link on an XY route: the mesh link leaving `from` in
/// direction `dir`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkHop {
    pub from: TileId,
    pub dir: Dir,
}

/// Allocation-free iterator over the directed links of the XY route from
/// `src` to `dst` (X first, then Y) — `hops(src, dst)` items, none for a
/// self-route. This is the engine's hot path: one iterator on the stack
/// per remote request, no `Vec`.
#[derive(Clone, Copy)]
pub struct XyLinks {
    grid_w: u32,
    cur: Coord,
    dst: Coord,
}

/// Directed links of the XY route from `src` to `dst` on `machine`.
///
/// # Examples
///
/// ```
/// use tilesim::arch::{Dir, Machine, TileId};
/// use tilesim::noc::xy_links;
///
/// let m = Machine::tilepro64();
/// // Tile 0 is (0,0); tile 10 is (2,1): two east hops, then one south.
/// let dirs: Vec<Dir> = xy_links(&m, TileId(0), TileId(10)).map(|h| h.dir).collect();
/// assert_eq!(dirs, [Dir::East, Dir::East, Dir::South]);
///
/// // A self-route crosses no links.
/// assert_eq!(xy_links(&m, TileId(9), TileId(9)).count(), 0);
/// ```
#[inline]
pub fn xy_links(machine: &Machine, src: TileId, dst: TileId) -> XyLinks {
    XyLinks {
        grid_w: machine.grid_w(),
        cur: machine.coord(src),
        dst: machine.coord(dst),
    }
}

impl Iterator for XyLinks {
    type Item = LinkHop;

    #[inline]
    fn next(&mut self) -> Option<LinkHop> {
        let from = TileId(self.cur.y * self.grid_w + self.cur.x);
        if self.cur.x != self.dst.x {
            let dir = if self.cur.x < self.dst.x {
                self.cur.x += 1;
                Dir::East
            } else {
                self.cur.x -= 1;
                Dir::West
            };
            return Some(LinkHop { from, dir });
        }
        if self.cur.y != self.dst.y {
            let dir = if self.cur.y < self.dst.y {
                self.cur.y += 1;
                Dir::South
            } else {
                self.cur.y -= 1;
                Dir::North
            };
            return Some(LinkHop { from, dir });
        }
        None
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = (self.cur.x.abs_diff(self.dst.x) + self.cur.y.abs_diff(self.dst.y)) as usize;
        (n, Some(n))
    }
}

impl ExactSizeIterator for XyLinks {}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Machine {
        Machine::tilepro64()
    }

    #[test]
    fn path_length_is_hops_plus_one() {
        let m = m();
        for (a, b) in [(0u32, 63u32), (5, 5), (7, 56), (10, 17)] {
            let p = xy_path(&m, TileId(a), TileId(b));
            assert_eq!(p.len() as u32, m.hops(TileId(a), TileId(b)) + 1);
            assert_eq!(p[0], TileId(a));
            assert_eq!(*p.last().unwrap(), TileId(b));
        }
    }

    #[test]
    fn path_moves_x_first() {
        let m = m();
        let p = xy_path(&m, TileId(0), TileId(63)); // (0,0) -> (7,7)
        // After the first 7 steps we must be at (7,0).
        assert_eq!(m.coord(p[7]), Coord { x: 7, y: 0 });
    }

    #[test]
    fn adjacent_steps_are_neighbours() {
        let m = m();
        let p = xy_path(&m, TileId(3), TileId(60));
        for w in p.windows(2) {
            assert_eq!(m.hops(w[0], w[1]), 1);
        }
    }

    #[test]
    fn self_path_is_singleton() {
        assert_eq!(xy_path(&m(), TileId(9), TileId(9)), vec![TileId(9)]);
    }

    #[test]
    fn links_mirror_path_segments() {
        // Every consecutive tile pair of xy_path is one LinkHop, in order,
        // with the direction implied by the coordinate delta.
        let m = m();
        for (a, b) in [(0u32, 63u32), (63, 0), (5, 5), (7, 56), (42, 17)] {
            let path = xy_path(&m, TileId(a), TileId(b));
            let links: Vec<LinkHop> = xy_links(&m, TileId(a), TileId(b)).collect();
            assert_eq!(links.len(), path.len() - 1);
            for (hop, pair) in links.iter().zip(path.windows(2)) {
                assert_eq!(hop.from, pair[0]);
                let (ca, cb) = (m.coord(pair[0]), m.coord(pair[1]));
                let dir = match () {
                    _ if cb.x > ca.x => Dir::East,
                    _ if cb.x < ca.x => Dir::West,
                    _ if cb.y > ca.y => Dir::South,
                    _ => Dir::North,
                };
                assert_eq!(hop.dir, dir);
            }
        }
    }

    #[test]
    fn links_on_non_square_grid() {
        let m = Machine::custom(4, 8, 2).unwrap();
        // (0,0) -> (3,7): 3 east hops then 7 south hops.
        let links: Vec<LinkHop> = xy_links(&m, TileId(0), TileId(31)).collect();
        assert_eq!(links.len(), 10);
        assert!(links[..3].iter().all(|h| h.dir == Dir::East));
        assert!(links[3..].iter().all(|h| h.dir == Dir::South));
        assert_eq!(xy_links(&m, TileId(9), TileId(9)).count(), 0);
    }

    #[test]
    fn links_size_hint_is_exact() {
        let m = m();
        let it = xy_links(&m, TileId(0), TileId(63));
        assert_eq!(it.len(), 14);
    }
}
