//! XY dimension-order routing over the 8×8 mesh.
//!
//! Latency uses the hop count (`arch::hops`); the explicit path is used by
//! tests and by the link-occupancy accounting in the contention model.

use crate::arch::{Coord, TileId};

/// Tiles traversed from `src` to `dst` under XY routing (X first, then Y),
/// inclusive of both endpoints.
pub fn xy_path(src: TileId, dst: TileId) -> Vec<TileId> {
    let a = src.coord();
    let b = dst.coord();
    let mut path = Vec::with_capacity((a.x.abs_diff(b.x) + a.y.abs_diff(b.y) + 1) as usize);
    let mut x = a.x;
    let y = a.y;
    path.push(src);
    while x != b.x {
        if x < b.x {
            x += 1;
        } else {
            x -= 1;
        }
        path.push(TileId::from_coord(Coord { x, y }));
    }
    let mut y = a.y;
    while y != b.y {
        if y < b.y {
            y += 1;
        } else {
            y -= 1;
        }
        path.push(TileId::from_coord(Coord { x: b.x, y }));
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::hops;

    #[test]
    fn path_length_is_hops_plus_one() {
        for (a, b) in [(0u32, 63u32), (5, 5), (7, 56), (10, 17)] {
            let p = xy_path(TileId(a), TileId(b));
            assert_eq!(p.len() as u32, hops(TileId(a), TileId(b)) + 1);
            assert_eq!(p[0], TileId(a));
            assert_eq!(*p.last().unwrap(), TileId(b));
        }
    }

    #[test]
    fn path_moves_x_first() {
        let p = xy_path(TileId(0), TileId(63)); // (0,0) -> (7,7)
        // After the first 7 steps we must be at (7,0).
        assert_eq!(p[7].coord(), Coord { x: 7, y: 0 });
    }

    #[test]
    fn adjacent_steps_are_neighbours() {
        let p = xy_path(TileId(3), TileId(60));
        for w in p.windows(2) {
            assert_eq!(hops(w[0], w[1]), 1);
        }
    }

    #[test]
    fn self_path_is_singleton() {
        assert_eq!(xy_path(TileId(9), TileId(9)), vec![TileId(9)]);
    }
}
