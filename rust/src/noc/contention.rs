//! Queueing contention for shared NoC resources, modelled as exact
//! serialisation across three server classes:
//!
//! - **home ports** — each tile's L2 coherence port (one server per tile);
//! - **memory controllers** — one server per DDR controller;
//! - **directional mesh links** — one server per directed link (four per
//!   tile: E/W/N/S), billed along the XY route of every remote request.
//!
//! Every server is deterministic: a request arriving at `now` starts at
//! `max(now, server_free_at)`; the wait is the queueing delay billed to
//! the requester. Server counts come from the runtime `Machine`
//! description, so any grid gets correctly-sized resource vectors.
//!
//! The replay engine processes threads min-clock-first in small quanta, so
//! requests arrive approximately in simulated-time order and the
//! serialisation is near-exact. Home-port queueing is what makes the
//! paper's disaster case (non-localised + local homing: 63 threads
//! hammering tile 0's L2 port) collapse to the port's service bandwidth
//! and what recreates the Fig. 4 controller crossover; link queueing is
//! what makes large grids (16×16 and up) hurt when traffic is *not*
//! localised — the mesh itself, not just the endpoints, saturates
//! (cf. Kommrusch et al., arXiv:2011.05422).

use std::sync::Arc;

use crate::arch::{Machine, TileId};
use crate::noc::routing::xy_links;

#[derive(Clone, Copy, Debug)]
pub struct ContentionConfig {
    /// Globally disable queueing (ablation: `--no-contention`).
    pub enabled: bool,
    /// Model per-link mesh contention (`--no-link-contention` clears it).
    /// The tilepro64 paper-baseline engine config leaves this off so the
    /// published fig1–fig4/table1 JSON replays byte-identically; machine
    /// presets and the grid-scaling sweep turn it on.
    pub links: bool,
}

impl Default for ContentionConfig {
    fn default() -> Self {
        ContentionConfig {
            enabled: true,
            links: true,
        }
    }
}

#[derive(Clone, Copy, Default)]
struct Server {
    free_at: u64,
    /// Latest arrival time seen — the server's notion of "now". Quantum
    /// replay delivers some requests with stale timestamps (a thread's
    /// clock can lag another's by up to a batch span); those are slotted
    /// at the arrival frontier so they are billed only genuine backlog,
    /// never the idle gap another thread's batch left behind.
    last_arrival: u64,
}

impl Server {
    /// Serve one request arriving at `now`; returns queueing delay.
    ///
    /// Delays are self-limiting under min-clock replay: a thread billed a
    /// wait advances its clock, so its next arrival is later — steady-state
    /// per-request delay converges to (concurrent requesters × service),
    /// exactly the hardware's backpressure behaviour.
    fn request(&mut self, now: u64, service: u64) -> u64 {
        let arrival = now.max(self.last_arrival);
        self.last_arrival = arrival;
        let start = arrival.max(self.free_at);
        self.free_at = start + service;
        start - arrival
    }
}

pub struct ContentionModel {
    cfg: ContentionConfig,
    machine: Arc<Machine>,
    homes: Vec<Server>,
    ctrls: Vec<Server>,
    /// One server per directed mesh link, indexed by `Machine::link_index`.
    links: Vec<Server>,
    link_service: u64,
    /// Total queueing cycles handed out (reporting).
    pub home_delay_cycles: u64,
    pub ctrl_delay_cycles: u64,
    pub link_delay_cycles: u64,
    /// Per-directed-link traffic counts (the hottest-link heatmap).
    pub link_requests: Vec<u64>,
}

impl ContentionModel {
    pub fn new(cfg: ContentionConfig, machine: Arc<Machine>) -> Self {
        let (homes, ctrls, links) = (
            machine.num_tiles() as usize,
            machine.num_controllers() as usize,
            machine.num_links(),
        );
        let link_service = machine.params.link_service;
        ContentionModel {
            cfg,
            machine,
            homes: vec![Server::default(); homes],
            ctrls: vec![Server::default(); ctrls],
            links: vec![Server::default(); links],
            link_service,
            home_delay_cycles: 0,
            ctrl_delay_cycles: 0,
            link_delay_cycles: 0,
            link_requests: vec![0; links],
        }
    }

    /// Whether link traversals are being billed.
    pub fn links_enabled(&self) -> bool {
        self.cfg.enabled && self.cfg.links
    }

    /// One request to `home`'s L2 port at time `now`; returns queue delay.
    pub fn home_request(&mut self, home: TileId, now: u64, service: u64) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.homes[home.index()].request(now, service);
        self.home_delay_cycles += d;
        d
    }

    /// One line request to controller `c` at time `now`.
    pub fn ctrl_request(&mut self, c: u32, now: u64, service: u64) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.ctrls[c as usize].request(now, service);
        self.ctrl_delay_cycles += d;
        d
    }

    /// Bill every directed link on the XY route `from → to` at time `now`;
    /// returns the total link queueing delay. Allocation-free (the route
    /// is walked by [`xy_links`]); a self-route bills nothing.
    #[inline]
    pub fn link_path_request(&mut self, from: TileId, to: TileId, now: u64) -> u64 {
        if !self.links_enabled() || from == to {
            return 0;
        }
        let mut delay = 0u64;
        for hop in xy_links(&self.machine, from, to) {
            let ix = self.machine.link_index(hop.from, hop.dir);
            delay += self.links[ix].request(now, self.link_service);
            self.link_requests[ix] += 1;
        }
        self.link_delay_cycles += delay;
        delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ContentionModel {
        ContentionModel::new(ContentionConfig::default(), Arc::new(Machine::tilepro64()))
    }

    #[test]
    fn uncontended_request_is_free() {
        let mut m = model();
        assert_eq!(m.home_request(TileId(0), 100, 2), 0);
        // Next request well after the first: still free.
        assert_eq!(m.home_request(TileId(0), 200, 2), 0);
    }

    #[test]
    fn back_to_back_requests_serialise() {
        let mut m = model();
        assert_eq!(m.home_request(TileId(0), 0, 2), 0);
        // Same instant: must wait for the 2-cycle service of the first.
        assert_eq!(m.home_request(TileId(0), 0, 2), 2);
        assert_eq!(m.home_request(TileId(0), 0, 2), 4);
    }

    #[test]
    fn hot_spot_collapses_to_service_bandwidth() {
        // 63 threads' worth of simultaneous traffic to one port: the k-th
        // request waits ~k*service — unbounded queueing, not a soft cap.
        let mut m = model();
        let mut last = 0;
        for _ in 0..1_000 {
            last = m.home_request(TileId(0), 0, 2);
        }
        assert!(last >= 1_900, "expected ~2k cycles of queue, got {last}");
    }

    #[test]
    fn queue_drains_over_time() {
        let mut m = model();
        for _ in 0..100 {
            m.home_request(TileId(0), 0, 2);
        }
        // Long after the burst: no residual delay.
        assert_eq!(m.home_request(TileId(0), 1_000_000, 2), 0);
    }

    #[test]
    fn resources_are_independent() {
        let mut m = model();
        for _ in 0..1_000 {
            m.home_request(TileId(0), 0, 2);
        }
        assert_eq!(m.home_request(TileId(1), 0, 2), 0);
        assert_eq!(m.ctrl_request(0, 0, 4), 0);
        assert_eq!(m.link_path_request(TileId(1), TileId(2), 0), 0);
    }

    #[test]
    fn disabled_model_is_free() {
        let mut m = ContentionModel::new(
            ContentionConfig {
                enabled: false,
                ..Default::default()
            },
            Arc::new(Machine::tilepro64()),
        );
        for _ in 0..10_000 {
            assert_eq!(m.home_request(TileId(0), 0, 2), 0);
            assert_eq!(m.link_path_request(TileId(0), TileId(63), 0), 0);
        }
        assert_eq!(m.home_delay_cycles, 0);
        assert_eq!(m.link_delay_cycles, 0);
    }

    #[test]
    fn spreading_load_beats_hot_spot() {
        let mut hot = model();
        for i in 0..64_000u64 {
            hot.home_request(TileId(0), i / 4, 2);
        }
        let mut spread = model();
        for i in 0..64_000u64 {
            spread.home_request(TileId((i % 64) as u32), i / 4, 2);
        }
        assert!(
            hot.home_delay_cycles > spread.home_delay_cycles * 10,
            "hot {} vs spread {}",
            hot.home_delay_cycles,
            spread.home_delay_cycles
        );
    }

    #[test]
    fn partially_drained_queue_charges_remainder() {
        let mut m = model();
        for _ in 0..100 {
            m.home_request(TileId(0), 0, 2); // frontier at 200
        }
        assert_eq!(m.home_request(TileId(0), 150, 2), 50);
    }

    #[test]
    fn link_self_route_is_free() {
        let mut m = model();
        assert_eq!(m.link_path_request(TileId(5), TileId(5), 0), 0);
        assert!(m.link_requests.iter().all(|&n| n == 0));
    }

    #[test]
    fn link_traffic_counts_every_hop() {
        let mut m = model();
        // (0,0) -> (7,7): 14 directed links, one count each.
        m.link_path_request(TileId(0), TileId(63), 0);
        assert_eq!(m.link_requests.iter().sum::<u64>(), 14);
    }

    #[test]
    fn shared_link_serialises_disjoint_endpoints() {
        // Two routes that share the first east link out of tile 0 must
        // queue on it even though their endpoints differ.
        let mut m = model();
        assert_eq!(m.link_path_request(TileId(0), TileId(2), 0), 0);
        let d = m.link_path_request(TileId(0), TileId(10), 0);
        assert!(d > 0, "shared E(0,0) link must queue, got {d}");
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        let mut m = model();
        assert_eq!(m.link_path_request(TileId(0), TileId(7), 0), 0);
        // The return route uses the west-facing links: independent servers.
        assert_eq!(m.link_path_request(TileId(7), TileId(0), 0), 0);
    }

    #[test]
    fn links_flag_disables_only_links() {
        let mut m = ContentionModel::new(
            ContentionConfig {
                enabled: true,
                links: false,
            },
            Arc::new(Machine::tilepro64()),
        );
        for _ in 0..100 {
            assert_eq!(m.link_path_request(TileId(0), TileId(63), 0), 0);
        }
        assert_eq!(m.link_delay_cycles, 0);
        // Home ports still serialise.
        m.home_request(TileId(0), 0, 2);
        assert_eq!(m.home_request(TileId(0), 0, 2), 2);
    }

    #[test]
    fn link_servers_sized_by_machine() {
        let m = ContentionModel::new(
            ContentionConfig::default(),
            Arc::new(Machine::custom(4, 8, 2).unwrap()),
        );
        assert_eq!(m.link_requests.len(), 4 * 32);
    }
}
