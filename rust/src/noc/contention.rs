//! Queueing contention for shared NoC resources, modelled as exact
//! serialisation across three server classes:
//!
//! - **home ports** — each tile's L2 coherence port (one server per tile);
//! - **memory controllers** — one server per DDR controller;
//! - **directional mesh links** — one server per directed link (four per
//!   tile: E/W/N/S), billed along the XY route of every mesh traversal.
//!
//! Link traffic is billed in three classes, each with its own per-link
//! counters so the heatmaps can show *what kind* of traffic saturates a
//! link:
//!
//! 1. **requests** — the forward route of every remote access
//!    ([`link_path_request`](ContentionModel::link_path_request));
//! 2. **replies** — the response route carrying data (loads) or an ack
//!    (stores), billed with a wormhole-pipelining approximation
//!    ([`reply_path_request`](ContentionModel::reply_path_request));
//! 3. **invalidations** — the home→sharer fan-out of a coherence write
//!    plus each sharer's ack return path
//!    ([`invalidation_fanout_request`](ContentionModel::invalidation_fanout_request)).
//!
//! Every server is deterministic: a request arriving at `now` starts at
//! `max(now, server_free_at)`; the wait is the queueing delay billed to
//! the requester. Server counts come from the runtime `Machine`
//! description, so any grid gets correctly-sized resource vectors — and
//! each link's *service time* comes from the machine's heterogeneous
//! [`Fabric`](crate::arch::Fabric) table (express rows/columns, wider
//! edge links, per-direction asymmetry), not a single scalar; a uniform
//! table reproduces the scalar model exactly.
//!
//! The replay engine processes threads min-clock-first in small quanta, so
//! requests arrive approximately in simulated-time order and the
//! serialisation is near-exact. Home-port queueing is what makes the
//! paper's disaster case (non-localised + local homing: 63 threads
//! hammering tile 0's L2 port) collapse to the port's service bandwidth
//! and what recreates the Fig. 4 controller crossover; link queueing is
//! what makes large grids (16×16 and up) hurt when traffic is *not*
//! localised — the mesh itself, not just the endpoints, saturates, and
//! directory-driven coherence traffic (classes 2 and 3) dominates mesh
//! occupancy at scale (cf. Kommrusch et al., arXiv:2011.05422).

use std::sync::Arc;

use crate::arch::{Machine, TileId};
use crate::noc::routing::xy_links;

#[derive(Clone, Copy, Debug)]
pub struct ContentionConfig {
    /// Globally disable queueing (ablation: `--no-contention`).
    pub enabled: bool,
    /// Model per-link mesh contention (`--no-link-contention` clears it).
    /// The tilepro64 paper-baseline engine config leaves this off so the
    /// published fig1–fig4/table1 JSON replays byte-identically; machine
    /// presets and the grid-scaling sweep turn it on.
    pub links: bool,
    /// Bill coherence traffic — invalidation fan-out (plus acks) and the
    /// reply path of reads/writes — through the link servers
    /// (`--no-coherence-links` clears it). Only meaningful when `links`
    /// is set; the paper-baseline config is unaffected either way.
    pub coherence: bool,
}

impl Default for ContentionConfig {
    fn default() -> Self {
        ContentionConfig {
            enabled: true,
            links: true,
            coherence: true,
        }
    }
}

#[derive(Clone, Copy, Default)]
struct Server {
    free_at: u64,
    /// Latest arrival time seen — the server's notion of "now". Quantum
    /// replay delivers some requests with stale timestamps (a thread's
    /// clock can lag another's by up to a batch span); those are slotted
    /// at the arrival frontier so they are billed only genuine backlog,
    /// never the idle gap another thread's batch left behind.
    last_arrival: u64,
}

impl Server {
    /// Serve one request arriving at `now`; returns queueing delay.
    ///
    /// Delays are self-limiting under min-clock replay: a thread billed a
    /// wait advances its clock, so its next arrival is later — steady-state
    /// per-request delay converges to (concurrent requesters × service),
    /// exactly the hardware's backpressure behaviour.
    fn request(&mut self, now: u64, service: u64) -> u64 {
        let arrival = now.max(self.last_arrival);
        self.last_arrival = arrival;
        let start = arrival.max(self.free_at);
        self.free_at = start + service;
        start - arrival
    }

    /// Serve `count` requests arriving at `now, now + stride, …`; returns
    /// the total queueing delay — exactly `sum(request(now + i*stride))`.
    ///
    /// The bulk replay path issues one request per line with a fixed
    /// inter-arrival stride (the uncontended per-line cost). Two regimes
    /// have closed forms, which is what makes page-run batching O(1)
    /// instead of O(lines):
    ///
    /// - **keeping up** (`stride >= service` and the first request finds
    ///   the server idle): every request starts on arrival, total delay 0;
    /// - **saturated** (`stride < service`): each request waits for the
    ///   previous one's service; the backlog grows arithmetically by
    ///   `service - stride` per request on top of any initial backlog.
    ///
    /// The mixed regime (initial backlog draining under `stride >=
    /// service`) falls back to the per-request loop; it lasts at most
    /// `backlog / (stride - service)` requests, so the fallback is rare
    /// and short on the paths that matter.
    fn request_batch(&mut self, now: u64, service: u64, stride: u64, count: u64) -> u64 {
        if count == 0 {
            return 0;
        }
        // Both closed forms need arrivals at exactly `now + i*stride`; a
        // frontier ahead of `now` (stale-timestamp batch) would clamp the
        // leading arrivals and break the arithmetic, so it takes the loop.
        if self.last_arrival <= now {
            if self.free_at <= now && stride >= service {
                // Keeping up from an idle start: no request ever queues.
                self.last_arrival = now + (count - 1) * stride;
                self.free_at = self.last_arrival + service;
                return 0;
            }
            if stride < service {
                // Saturated: request i arrives at now + i*stride and starts
                // at max(now, free_at) + i*service. Sum the arithmetic
                // series of waits directly.
                let start0 = now.max(self.free_at);
                let base = start0 - now;
                let step = service - stride;
                // sum_{i=0}^{count-1} (base + i*step)
                let total = count * base + step * (count * (count - 1) / 2);
                self.last_arrival = now + (count - 1) * stride;
                self.free_at = start0 + count * service;
                return total;
            }
        }
        // Mixed regime (backlog draining, or a stale arrival frontier):
        // loop — bounded by the initial backlog / frontier gap.
        let mut total = 0;
        for i in 0..count {
            total += self.request(now + i * stride, service);
        }
        total
    }

    /// Would `count` requests at `now, now + stride, …` all sail through
    /// with zero queueing? True iff the server is idle at `now` (no
    /// backlog, no future arrival frontier) and keeps up with the
    /// arrival rate.
    fn keeps_up(&self, now: u64, service: u64, stride: u64) -> bool {
        self.last_arrival <= now && self.free_at <= now && service <= stride
    }

    /// Book the occupancy of a zero-queueing batch (caller checked
    /// [`keeps_up`](Self::keeps_up)): state lands exactly where `count`
    /// individual zero-delay requests would leave it.
    fn book_batch(&mut self, now: u64, service: u64, stride: u64, count: u64) {
        self.last_arrival = now + (count - 1) * stride;
        self.free_at = self.last_arrival + service;
    }
}

pub struct ContentionModel {
    cfg: ContentionConfig,
    machine: Arc<Machine>,
    homes: Vec<Server>,
    ctrls: Vec<Server>,
    /// One server per directed mesh link, indexed by `Machine::link_index`.
    links: Vec<Server>,
    /// Per-link service times, copied out of the machine's `Fabric` (one
    /// indexed load per billing, no `Arc` hop on the hot path). A uniform
    /// table at `params.link_service` reproduces the pre-fabric scalar
    /// billing exactly.
    link_service: Vec<u64>,
    hop_cycles: u64,
    /// Total queueing cycles handed out (reporting).
    pub home_delay_cycles: u64,
    pub ctrl_delay_cycles: u64,
    /// Queueing on forward (request-class) link traversals.
    pub link_delay_cycles: u64,
    /// Cycles billed to reply-path traversals (queueing + wormhole payload
    /// excess over the already-billed header latency).
    pub reply_link_cycles: u64,
    /// Queueing cycles billed to invalidation fan-out + ack traversals.
    pub invalidation_link_cycles: u64,
    /// Cycles billed to write-update data fan-out (queueing + data-packet
    /// serialisation); zero unless a write-update protocol ran.
    pub update_fanout_cycles: u64,
    /// Per-directed-link traffic counts by class (the hottest-link
    /// heatmaps): forward requests, replies, invalidations+acks.
    pub link_requests: Vec<u64>,
    pub link_reply_requests: Vec<u64>,
    pub link_inval_requests: Vec<u64>,
}

impl ContentionModel {
    pub fn new(cfg: ContentionConfig, machine: Arc<Machine>) -> Self {
        let (homes, ctrls, links) = (
            machine.num_tiles() as usize,
            machine.num_controllers() as usize,
            machine.num_links(),
        );
        let link_service: Vec<u64> = (0..links).map(|ix| machine.fabric().service(ix)).collect();
        let hop_cycles = machine.params.noc_hop;
        ContentionModel {
            cfg,
            machine,
            homes: vec![Server::default(); homes],
            ctrls: vec![Server::default(); ctrls],
            links: vec![Server::default(); links],
            link_service,
            hop_cycles,
            home_delay_cycles: 0,
            ctrl_delay_cycles: 0,
            link_delay_cycles: 0,
            reply_link_cycles: 0,
            invalidation_link_cycles: 0,
            update_fanout_cycles: 0,
            link_requests: vec![0; links],
            link_reply_requests: vec![0; links],
            link_inval_requests: vec![0; links],
        }
    }

    /// Whether link traversals are being billed.
    pub fn links_enabled(&self) -> bool {
        self.cfg.enabled && self.cfg.links
    }

    /// Whether coherence traffic (invalidations, replies) is billed on the
    /// links. Implies [`links_enabled`](Self::links_enabled).
    pub fn coherence_enabled(&self) -> bool {
        self.links_enabled() && self.cfg.coherence
    }

    /// One request to `home`'s L2 port at time `now`; returns queue delay.
    pub fn home_request(&mut self, home: TileId, now: u64, service: u64) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.homes[home.index()].request(now, service);
        self.home_delay_cycles += d;
        d
    }

    /// One line request to controller `c` at time `now`.
    pub fn ctrl_request(&mut self, c: u32, now: u64, service: u64) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.ctrls[c as usize].request(now, service);
        self.ctrl_delay_cycles += d;
        d
    }

    /// `count` requests to `home`'s L2 port arriving at `now, now + stride,
    /// …`; returns the total queueing delay — identical to calling
    /// [`home_request`](Self::home_request) `count` times, but O(1) in the
    /// common regimes (see `Server::request_batch`). The bulk replay
    /// path uses this to bill a whole page run in one call.
    pub fn home_request_batch(
        &mut self,
        home: TileId,
        now: u64,
        service: u64,
        stride: u64,
        count: u64,
    ) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.homes[home.index()].request_batch(now, service, stride, count);
        self.home_delay_cycles += d;
        d
    }

    /// `count` line requests to controller `c` arriving at `now, now +
    /// stride, …`; the batch analogue of [`ctrl_request`](Self::ctrl_request).
    pub fn ctrl_request_batch(
        &mut self,
        c: u32,
        now: u64,
        service: u64,
        stride: u64,
        count: u64,
    ) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.ctrls[c as usize].request_batch(now, service, stride, count);
        self.ctrl_delay_cycles += d;
        d
    }

    /// Try to bill a whole uncached run in O(1): `count` line
    /// transactions arriving at `now, now + stride, …`, each occupying
    /// `home`'s L2 port (when `Some` — remote-homed runs) and controller
    /// `c`. Commits and returns `true` only when *every* touched server
    /// is idle at `now` and keeps up with the stride, i.e. when the
    /// per-line walk would have billed exactly zero delay — which also
    /// means the per-line arrival times (each fed by the previous line's
    /// delay) degenerate to the fixed stride this probe assumes, so the
    /// final server state is bit-identical to the walk's. On `false`
    /// nothing changes and the caller must bill per line. Requires link
    /// billing to be off: link servers are not modelled here.
    #[allow(clippy::too_many_arguments)]
    pub fn try_zero_delay_batch(
        &mut self,
        home: Option<TileId>,
        home_service: u64,
        c: u32,
        ctrl_service: u64,
        now: u64,
        stride: u64,
        count: u64,
    ) -> bool {
        if !self.cfg.enabled || count == 0 {
            return true;
        }
        if self.links_enabled() {
            return false;
        }
        if let Some(h) = home {
            if !self.homes[h.index()].keeps_up(now, home_service, stride) {
                return false;
            }
        }
        if !self.ctrls[c as usize].keeps_up(now, ctrl_service, stride) {
            return false;
        }
        if let Some(h) = home {
            self.homes[h.index()].book_batch(now, home_service, stride, count);
        }
        self.ctrls[c as usize].book_batch(now, ctrl_service, stride, count);
        true
    }

    /// Bill every directed link on the XY route `from → to` at time `now`;
    /// returns the total link queueing delay. Allocation-free (the route
    /// is walked by [`xy_links`]); a self-route bills nothing.
    #[inline]
    pub fn link_path_request(&mut self, from: TileId, to: TileId, now: u64) -> u64 {
        if !self.links_enabled() || from == to {
            return 0;
        }
        let mut delay = 0u64;
        for hop in xy_links(&self.machine, from, to) {
            let ix = self.machine.link_index(hop.from, hop.dir);
            delay += self.links[ix].request(now, self.link_service[ix]);
            self.link_requests[ix] += 1;
        }
        self.link_delay_cycles += delay;
        delay
    }

    /// Bill the response route `from → to` (home or controller attach back
    /// to the requester) carrying a `flits`-flit payload at time `now`;
    /// returns the cycles added to the requester.
    ///
    /// Occupancy is billed per directed link exactly like a forward
    /// request, but the traversal *latency* uses a wormhole-pipelining
    /// approximation instead of a second serial walk: the payload streams
    /// behind the header at the rate of the route's *slowest* link, so the
    /// route costs `max(header_hops · noc_hop, flits · max_link_service)`
    /// (on a uniform fabric this is the old scalar formula). The header
    /// term is already part of the uncontended `access_cycles` round trip,
    /// so only the payload-serialisation *excess* over it is returned
    /// (plus any queueing) — with `flits == 1` (a pure ack) over unit-
    /// service links the excess is zero and the reply adds only genuine
    /// backlog.
    #[inline]
    pub fn reply_path_request(&mut self, from: TileId, to: TileId, now: u64, flits: u64) -> u64 {
        if !self.coherence_enabled() || from == to {
            return 0;
        }
        let mut queue = 0u64;
        let mut hops = 0u64;
        let mut max_service = 0u64;
        for hop in xy_links(&self.machine, from, to) {
            let ix = self.machine.link_index(hop.from, hop.dir);
            let service = self.link_service[ix];
            queue += self.links[ix].request(now, service);
            max_service = max_service.max(service);
            self.link_reply_requests[ix] += 1;
            hops += 1;
        }
        let header = hops * self.hop_cycles;
        let d = queue + (flits * max_service).saturating_sub(header);
        self.reply_link_cycles += d;
        d
    }

    /// Bill a write's invalidation fan-out at time `now`: one header-sized
    /// packet along the XY route home→sharer per invalidated tile, plus
    /// the sharer→home ack return path (the directory's `write_claim` /
    /// `fanout` pair supplies `victims`). Returns the total queueing delay
    /// billed to the writer — the store is not globally visible until the
    /// last ack lands, so fan-out backlog is the writer's to pay. A victim
    /// on the home tile itself crosses no links.
    pub fn invalidation_fanout_request(
        &mut self,
        home: TileId,
        victims: &[TileId],
        now: u64,
    ) -> u64 {
        if !self.coherence_enabled() || victims.is_empty() {
            return 0;
        }
        let mut delay = 0u64;
        for &v in victims {
            for hop in xy_links(&self.machine, home, v) {
                let ix = self.machine.link_index(hop.from, hop.dir);
                delay += self.links[ix].request(now, self.link_service[ix]);
                self.link_inval_requests[ix] += 1;
            }
            for hop in xy_links(&self.machine, v, home) {
                let ix = self.machine.link_index(hop.from, hop.dir);
                delay += self.links[ix].request(now, self.link_service[ix]);
                self.link_inval_requests[ix] += 1;
            }
        }
        self.invalidation_link_cycles += delay;
        delay
    }

    /// Single-victim specialization of
    /// [`invalidation_fanout_request`](Self::invalidation_fanout_request):
    /// one header packet requestor→home plus the ack return path. This is
    /// the MSI upgrade round trip the page-run fast path bills once per
    /// line of a batched run — a dedicated entry point so the hot loop
    /// never builds a one-element slice. Arithmetic-identical to
    /// `invalidation_fanout_request(home, &[victim], now)` (pinned by a
    /// unit test).
    #[inline]
    pub fn invalidation_roundtrip_request(
        &mut self,
        home: TileId,
        victim: TileId,
        now: u64,
    ) -> u64 {
        if !self.coherence_enabled() {
            return 0;
        }
        let mut delay = 0u64;
        for hop in xy_links(&self.machine, home, victim) {
            let ix = self.machine.link_index(hop.from, hop.dir);
            delay += self.links[ix].request(now, self.link_service[ix]);
            self.link_inval_requests[ix] += 1;
        }
        for hop in xy_links(&self.machine, victim, home) {
            let ix = self.machine.link_index(hop.from, hop.dir);
            delay += self.links[ix].request(now, self.link_service[ix]);
            self.link_inval_requests[ix] += 1;
        }
        self.invalidation_link_cycles += delay;
        delay
    }

    /// Bill a write-update protocol's data fan-out at time `now`: a
    /// `flits`-flit update packet along the XY route home→sharer per
    /// victim — each link stays busy `flits × service` (data, not a
    /// header), so the bandwidth cost of updating instead of
    /// invalidating surfaces as queueing on everything behind it — plus
    /// the sharer→home ack return path. Traffic rides the
    /// invalidation-class per-link counters (it is the protocol's
    /// replacement for that traffic), but its queueing cycles are
    /// tallied separately in
    /// [`update_fanout_cycles`](Self::update_fanout_cycles) so reports
    /// can attribute them. Returns the queueing delay billed to the
    /// writer.
    pub fn update_fanout_request(
        &mut self,
        home: TileId,
        victims: &[TileId],
        now: u64,
        flits: u64,
    ) -> u64 {
        if !self.coherence_enabled() || victims.is_empty() {
            return 0;
        }
        let mut delay = 0u64;
        for &v in victims {
            for hop in xy_links(&self.machine, home, v) {
                let ix = self.machine.link_index(hop.from, hop.dir);
                delay += self.links[ix].request(now, flits * self.link_service[ix]);
                self.link_inval_requests[ix] += 1;
            }
            for hop in xy_links(&self.machine, v, home) {
                let ix = self.machine.link_index(hop.from, hop.dir);
                delay += self.links[ix].request(now, self.link_service[ix]);
                self.link_inval_requests[ix] += 1;
            }
        }
        self.update_fanout_cycles += delay;
        delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ContentionModel {
        ContentionModel::new(ContentionConfig::default(), Arc::new(Machine::tilepro64()))
    }

    fn model_on(machine: Machine, cfg: ContentionConfig) -> ContentionModel {
        ContentionModel::new(cfg, Arc::new(machine))
    }

    #[test]
    fn uncontended_request_is_free() {
        let mut m = model();
        assert_eq!(m.home_request(TileId(0), 100, 2), 0);
        // Next request well after the first: still free.
        assert_eq!(m.home_request(TileId(0), 200, 2), 0);
    }

    #[test]
    fn back_to_back_requests_serialise() {
        let mut m = model();
        assert_eq!(m.home_request(TileId(0), 0, 2), 0);
        // Same instant: must wait for the 2-cycle service of the first.
        assert_eq!(m.home_request(TileId(0), 0, 2), 2);
        assert_eq!(m.home_request(TileId(0), 0, 2), 4);
    }

    #[test]
    fn hot_spot_collapses_to_service_bandwidth() {
        // 63 threads' worth of simultaneous traffic to one port: the k-th
        // request waits ~k*service — unbounded queueing, not a soft cap.
        let mut m = model();
        let mut last = 0;
        for _ in 0..1_000 {
            last = m.home_request(TileId(0), 0, 2);
        }
        assert!(last >= 1_900, "expected ~2k cycles of queue, got {last}");
    }

    #[test]
    fn queue_drains_over_time() {
        let mut m = model();
        for _ in 0..100 {
            m.home_request(TileId(0), 0, 2);
        }
        // Long after the burst: no residual delay.
        assert_eq!(m.home_request(TileId(0), 1_000_000, 2), 0);
    }

    #[test]
    fn resources_are_independent() {
        let mut m = model();
        for _ in 0..1_000 {
            m.home_request(TileId(0), 0, 2);
        }
        assert_eq!(m.home_request(TileId(1), 0, 2), 0);
        assert_eq!(m.ctrl_request(0, 0, 4), 0);
        assert_eq!(m.link_path_request(TileId(1), TileId(2), 0), 0);
    }

    #[test]
    fn disabled_model_is_free() {
        let mut m = ContentionModel::new(
            ContentionConfig {
                enabled: false,
                ..Default::default()
            },
            Arc::new(Machine::tilepro64()),
        );
        for _ in 0..10_000 {
            assert_eq!(m.home_request(TileId(0), 0, 2), 0);
            assert_eq!(m.link_path_request(TileId(0), TileId(63), 0), 0);
            assert_eq!(m.reply_path_request(TileId(63), TileId(0), 0, 4), 0);
            assert_eq!(
                m.invalidation_fanout_request(TileId(0), &[TileId(63)], 0),
                0
            );
        }
        assert_eq!(m.home_delay_cycles, 0);
        assert_eq!(m.link_delay_cycles, 0);
        assert_eq!(m.reply_link_cycles, 0);
        assert_eq!(m.invalidation_link_cycles, 0);
    }

    #[test]
    fn spreading_load_beats_hot_spot() {
        let mut hot = model();
        for i in 0..64_000u64 {
            hot.home_request(TileId(0), i / 4, 2);
        }
        let mut spread = model();
        for i in 0..64_000u64 {
            spread.home_request(TileId((i % 64) as u32), i / 4, 2);
        }
        assert!(
            hot.home_delay_cycles > spread.home_delay_cycles * 10,
            "hot {} vs spread {}",
            hot.home_delay_cycles,
            spread.home_delay_cycles
        );
    }

    #[test]
    fn partially_drained_queue_charges_remainder() {
        let mut m = model();
        for _ in 0..100 {
            m.home_request(TileId(0), 0, 2); // frontier at 200
        }
        assert_eq!(m.home_request(TileId(0), 150, 2), 50);
    }

    #[test]
    fn link_self_route_is_free() {
        let mut m = model();
        assert_eq!(m.link_path_request(TileId(5), TileId(5), 0), 0);
        assert_eq!(m.reply_path_request(TileId(5), TileId(5), 0, 4), 0);
        assert!(m.link_requests.iter().all(|&n| n == 0));
        assert!(m.link_reply_requests.iter().all(|&n| n == 0));
    }

    #[test]
    fn link_traffic_counts_every_hop() {
        let mut m = model();
        // (0,0) -> (7,7): 14 directed links, one count each.
        m.link_path_request(TileId(0), TileId(63), 0);
        assert_eq!(m.link_requests.iter().sum::<u64>(), 14);
    }

    #[test]
    fn shared_link_serialises_disjoint_endpoints() {
        // Two routes that share the first east link out of tile 0 must
        // queue on it even though their endpoints differ.
        let mut m = model();
        assert_eq!(m.link_path_request(TileId(0), TileId(2), 0), 0);
        let d = m.link_path_request(TileId(0), TileId(10), 0);
        assert!(d > 0, "shared E(0,0) link must queue, got {d}");
    }

    #[test]
    fn opposite_directions_do_not_contend() {
        let mut m = model();
        assert_eq!(m.link_path_request(TileId(0), TileId(7), 0), 0);
        // The return route uses the west-facing links: independent servers.
        assert_eq!(m.link_path_request(TileId(7), TileId(0), 0), 0);
    }

    #[test]
    fn links_flag_disables_only_links() {
        let mut m = ContentionModel::new(
            ContentionConfig {
                enabled: true,
                links: false,
                coherence: true,
            },
            Arc::new(Machine::tilepro64()),
        );
        for _ in 0..100 {
            assert_eq!(m.link_path_request(TileId(0), TileId(63), 0), 0);
            // Coherence billing rides on the link servers: links off means
            // the reply/invalidation classes are off too.
            assert_eq!(m.reply_path_request(TileId(63), TileId(0), 0, 4), 0);
            assert_eq!(
                m.invalidation_fanout_request(TileId(0), &[TileId(9)], 0),
                0
            );
        }
        assert_eq!(m.link_delay_cycles, 0);
        assert_eq!(m.reply_link_cycles, 0);
        assert_eq!(m.invalidation_link_cycles, 0);
        assert!(!m.coherence_enabled());
        // Home ports still serialise.
        m.home_request(TileId(0), 0, 2);
        assert_eq!(m.home_request(TileId(0), 0, 2), 2);
    }

    #[test]
    fn coherence_flag_disables_only_coherence_classes() {
        let mut m = ContentionModel::new(
            ContentionConfig {
                enabled: true,
                links: true,
                coherence: false,
            },
            Arc::new(Machine::tilepro64()),
        );
        assert!(m.links_enabled() && !m.coherence_enabled());
        assert_eq!(m.reply_path_request(TileId(63), TileId(0), 0, 4), 0);
        assert_eq!(m.invalidation_fanout_request(TileId(0), &[TileId(9)], 0), 0);
        assert!(m.link_reply_requests.iter().all(|&n| n == 0));
        assert!(m.link_inval_requests.iter().all(|&n| n == 0));
        // Forward requests still bill and queue.
        m.link_path_request(TileId(0), TileId(2), 0);
        assert!(m.link_path_request(TileId(0), TileId(2), 0) > 0);
    }

    #[test]
    fn roundtrip_is_the_one_victim_fanout() {
        // The fast path's dedicated upgrade round trip must be
        // arithmetic-identical to the slice call it specialises: same
        // delay, same per-link counters, same tally — on empty links,
        // against a backlog, and on the degenerate victim == home route.
        for (home, victim) in [(0u32, 9u32), (0, 63), (5, 5), (63, 0)] {
            let mut a = model();
            let mut b = model();
            // Pre-load a shared link so queueing delays are exercised.
            a.link_path_request(TileId(0), TileId(63), 0);
            b.link_path_request(TileId(0), TileId(63), 0);
            for now in [0u64, 3, 10] {
                assert_eq!(
                    a.invalidation_roundtrip_request(TileId(home), TileId(victim), now),
                    b.invalidation_fanout_request(TileId(home), &[TileId(victim)], now),
                    "home {home} victim {victim} now {now}"
                );
            }
            assert_eq!(a.invalidation_link_cycles, b.invalidation_link_cycles);
            assert_eq!(a.link_inval_requests, b.link_inval_requests);
        }
        // Coherence off: both entry points are free.
        let mut m = ContentionModel::new(
            ContentionConfig {
                enabled: true,
                links: true,
                coherence: false,
            },
            Arc::new(Machine::tilepro64()),
        );
        assert_eq!(m.invalidation_roundtrip_request(TileId(0), TileId(9), 0), 0);
        assert!(m.link_inval_requests.iter().all(|&n| n == 0));
    }

    #[test]
    fn reply_pure_ack_adds_no_uncontended_cycles() {
        // flits == 1 on empty links: occupancy is booked, zero delay (the
        // header latency is already in access_cycles).
        let mut m = model();
        assert_eq!(m.reply_path_request(TileId(63), TileId(0), 0, 1), 0);
        assert_eq!(m.link_reply_requests.iter().sum::<u64>(), 14);
        assert_eq!(m.reply_link_cycles, 0);
    }

    #[test]
    fn reply_payload_excess_only_on_short_routes() {
        // tilepro64: noc_hop == link_service == 1, 4-flit lines. A 1-hop
        // reply pays max(1, 4) - 1 = 3 extra cycles of payload streaming;
        // a 14-hop reply pays none (the header latency covers it).
        let mut m = model();
        assert_eq!(m.reply_path_request(TileId(1), TileId(0), 0, 4), 3);
        let mut far = model();
        assert_eq!(far.reply_path_request(TileId(63), TileId(0), 0, 4), 0);
    }

    #[test]
    fn reply_and_request_share_link_servers() {
        // A reply occupies the same directional servers as forward traffic
        // in its direction: a west-bound reply delays a west-bound request.
        let mut m = model();
        assert_eq!(m.reply_path_request(TileId(7), TileId(0), 0, 1), 0);
        let d = m.link_path_request(TileId(7), TileId(0), 0);
        assert!(d > 0, "request behind a reply must queue, got {d}");
    }

    #[test]
    fn invalidation_fanout_hand_computed_on_4x4() {
        // Home (0,0) invalidates sharers (1,0), (2,0), (3,0) on a 4×4 grid
        // at now=0, service 1 (service != 1 on the epiphany16 preset's
        // params, so build the grid explicitly). Fan-out packets share the
        // east row links, acks share the west ones:
        //   victim 1: E(0,0)=0                | ack W(1,0)=0
        //   victim 2: E(0,0)=1, E(1,0)=0      | ack W(2,0)=0, W(1,0)=1
        //   victim 3: E(0,0)=2, E(1,0)=1,     | ack W(3,0)=0, W(2,0)=1,
        //             E(2,0)=0                |     W(1,0)=2
        // Total queueing = 8; 6 fan-out + 6 ack link crossings.
        let mut m = model_on(
            Machine::custom(4, 4, 2).unwrap(),
            ContentionConfig::default(),
        );
        let victims = [TileId(1), TileId(2), TileId(3)];
        let d = m.invalidation_fanout_request(TileId(0), &victims, 0);
        assert_eq!(d, 8);
        assert_eq!(m.invalidation_link_cycles, 8);
        assert_eq!(m.link_inval_requests.iter().sum::<u64>(), 12);
        // Request/reply classes untouched.
        assert_eq!(m.link_requests.iter().sum::<u64>(), 0);
        assert_eq!(m.link_reply_requests.iter().sum::<u64>(), 0);
    }

    #[test]
    fn invalidation_traffic_counts_round_trip_hops() {
        // Sharer sets {1..=n} from home 0 on a 4×4 grid: every victim v in
        // row 0 is v hops out, so fan-out + ack cross 2 * sum(hops) links.
        for n in 1..=3u32 {
            let mut m = model_on(
                Machine::custom(4, 4, 2).unwrap(),
                ContentionConfig::default(),
            );
            let victims: Vec<TileId> = (1..=n).map(TileId).collect();
            m.invalidation_fanout_request(TileId(0), &victims, 0);
            let expect: u64 = (1..=n as u64).map(|h| 2 * h).sum();
            assert_eq!(
                m.link_inval_requests.iter().sum::<u64>(),
                expect,
                "n={n}"
            );
        }
    }

    #[test]
    fn update_fanout_occupies_links_flits_long() {
        // Home (0,0) updates sharers (1,0) and (2,0) on a 4×4 grid with
        // 4-flit packets. E(0,0) serves victim 1's data for 4 cycles, so
        // victim 2's packet queues 4 behind it; every other link is
        // first-use. Acks are header-sized and share the west links:
        // W(1,0) carries victim 1's ack at 0 and victim 2's at 1 — the
        // 4-cycle data occupancy delays nothing there (opposite class
        // direction), so queueing = 4 (E00) + 1 (W10) = 5.
        let mut m = model_on(
            Machine::custom(4, 4, 2).unwrap(),
            ContentionConfig::default(),
        );
        let d = m.update_fanout_request(TileId(0), &[TileId(1), TileId(2)], 0, 4);
        assert_eq!(d, 5);
        assert_eq!(m.update_fanout_cycles, 5);
        // 1 + 2 data hops out, 1 + 2 ack hops back.
        assert_eq!(m.link_inval_requests.iter().sum::<u64>(), 6);
        // The invalidation-cycle tally is untouched: classes separate.
        assert_eq!(m.invalidation_link_cycles, 0);
    }

    #[test]
    fn update_fanout_respects_the_coherence_gate() {
        for cfg in [
            ContentionConfig {
                enabled: true,
                links: true,
                coherence: false,
            },
            ContentionConfig {
                enabled: true,
                links: false,
                coherence: true,
            },
        ] {
            let mut m = model_on(Machine::tilepro64(), cfg);
            assert_eq!(
                m.update_fanout_request(TileId(0), &[TileId(9)], 0, 4),
                0
            );
            assert_eq!(m.update_fanout_cycles, 0);
            assert!(m.link_inval_requests.iter().all(|&n| n == 0));
        }
        // Victim on the home tile crosses no links.
        let mut m = model();
        assert_eq!(m.update_fanout_request(TileId(5), &[TileId(5)], 0, 4), 0);
    }

    #[test]
    fn invalidation_victim_on_home_tile_is_free() {
        let mut m = model();
        assert_eq!(m.invalidation_fanout_request(TileId(5), &[TileId(5)], 0), 0);
        assert_eq!(m.link_inval_requests.iter().sum::<u64>(), 0);
    }

    #[test]
    fn fabric_express_links_never_queue() {
        // base 1 halved floors to a zero-service express row: row-0 east
        // traffic books occupancy but no backlog, while an ordinary
        // column still serialises.
        let machine = Machine::tilepro64()
            .with_fabric(&crate::arch::FabricSpec::parse("express-row=0@0.5").unwrap())
            .unwrap();
        let mut m = model_on(machine, ContentionConfig::default());
        assert_eq!(m.link_path_request(TileId(0), TileId(7), 0), 0);
        assert_eq!(
            m.link_path_request(TileId(0), TileId(7), 0),
            0,
            "express row must not queue"
        );
        assert_eq!(m.link_requests.iter().sum::<u64>(), 14);
        assert_eq!(m.link_path_request(TileId(0), TileId(56), 0), 0);
        assert!(
            m.link_path_request(TileId(0), TileId(56), 0) > 0,
            "unit-service column must still serialise"
        );
    }

    #[test]
    fn fabric_slow_links_queue_longer() {
        let machine = Machine::tilepro64()
            .with_fabric(&crate::arch::FabricSpec::parse("base=4").unwrap())
            .unwrap();
        let mut slow = model_on(machine, ContentionConfig::default());
        assert_eq!(slow.link_path_request(TileId(0), TileId(1), 0), 0);
        // The 4-cycle link is busy 4 cycles; the scalar model billed 1.
        assert_eq!(slow.link_path_request(TileId(0), TileId(1), 0), 4);
        let mut unit = model();
        unit.link_path_request(TileId(0), TileId(1), 0);
        assert_eq!(unit.link_path_request(TileId(0), TileId(1), 0), 1);
    }

    #[test]
    fn reply_wormhole_streams_at_the_slowest_link() {
        // West links at service 4: a 2-hop 4-flit reply pays
        // max(2*noc_hop, 4*4) - 2 = 14 cycles of payload excess.
        let machine = Machine::tilepro64()
            .with_fabric(&crate::arch::FabricSpec::parse("dir=W@4").unwrap())
            .unwrap();
        let mut m = model_on(machine, ContentionConfig::default());
        assert_eq!(m.reply_path_request(TileId(2), TileId(0), 0, 4), 14);
        // An east-bound reply over unit links keeps the scalar behaviour.
        assert_eq!(m.reply_path_request(TileId(61), TileId(63), 0, 4), 2);
    }

    /// Exhaustive pin: `request_batch` must equal the per-request loop in
    /// total delay *and* leave the server in the same state, across every
    /// regime — idle/keeping-up, saturated, draining backlog, and a stale
    /// arrival frontier.
    #[test]
    fn batch_request_matches_per_request_loop() {
        let cases: &[(u64, u64, u64, u64, u64, u64)] = &[
            // (free_at, last_arrival, now, service, stride, count)
            (0, 0, 100, 2, 4, 50),    // idle, keeping up -> closed form 0
            (0, 0, 100, 2, 2, 50),    // stride == service boundary
            (0, 0, 100, 4, 1, 100),   // saturated from idle
            (500, 0, 100, 4, 1, 100), // saturated behind a backlog
            (500, 0, 100, 2, 4, 300), // backlog draining -> loop fallback
            (500, 0, 100, 2, 4, 10),  // backlog not fully drained
            (0, 400, 100, 2, 4, 50),  // stale frontier -> loop fallback
            (300, 400, 100, 3, 1, 40), // stale frontier + backlog, saturated
            (0, 0, 0, 0, 0, 17),      // degenerate zero service/stride
            (0, 0, 5, 3, 0, 25),      // simultaneous arrivals (stride 0)
            (0, 0, 9, 2, 4, 1),       // single-request batch
            (7, 3, 9, 2, 4, 0),       // empty batch is a no-op
        ];
        for &(free_at, last_arrival, now, service, stride, count) in cases {
            let mut a = Server {
                free_at,
                last_arrival,
            };
            let mut b = a;
            let batch = a.request_batch(now, service, stride, count);
            let mut looped = 0;
            for i in 0..count {
                looped += b.request(now + i * stride, service);
            }
            assert_eq!(
                batch, looped,
                "delay mismatch: free_at={free_at} last={last_arrival} \
                 now={now} svc={service} stride={stride} n={count}"
            );
            if count > 0 {
                assert_eq!(a.free_at, b.free_at, "free_at diverged: n={count} svc={service}");
                assert_eq!(
                    a.last_arrival, b.last_arrival,
                    "last_arrival diverged: n={count} svc={service}"
                );
            }
        }
    }

    #[test]
    fn batch_entry_points_tally_like_singles() {
        let mut batch = model();
        let mut single = model();
        let d = batch.home_request_batch(TileId(3), 0, 2, 1, 100);
        let mut s = 0;
        for i in 0..100 {
            s += single.home_request(TileId(3), i, 2);
        }
        assert_eq!(d, s);
        assert_eq!(batch.home_delay_cycles, single.home_delay_cycles);
        let d = batch.ctrl_request_batch(1, 0, 4, 1, 64);
        let mut s = 0;
        for i in 0..64 {
            s += single.ctrl_request(1, i, 4);
        }
        assert_eq!(d, s);
        assert_eq!(batch.ctrl_delay_cycles, single.ctrl_delay_cycles);
        // Disabled model: free and state-less, like the single-shot path.
        let mut off = ContentionModel::new(
            ContentionConfig {
                enabled: false,
                ..Default::default()
            },
            Arc::new(Machine::tilepro64()),
        );
        assert_eq!(off.home_request_batch(TileId(0), 0, 2, 0, 1_000), 0);
        assert_eq!(off.ctrl_request_batch(0, 0, 2, 0, 1_000), 0);
        assert_eq!(off.home_delay_cycles, 0);
        assert_eq!(off.ctrl_delay_cycles, 0);
    }

    #[test]
    fn zero_delay_batch_matches_idle_walk() {
        let cfg = ContentionConfig {
            enabled: true,
            links: false,
            coherence: false,
        };
        let mut a = model_on(Machine::tilepro64(), cfg);
        let mut b = model_on(Machine::tilepro64(), cfg);
        // Idle servers keeping up: the probe commits, and the per-line
        // walk it replaces bills zero.
        assert!(a.try_zero_delay_batch(Some(TileId(9)), 2, 1, 4, 100, 8, 50));
        let mut walk = 0;
        for i in 0..50u64 {
            walk += b.home_request(TileId(9), 100 + i * 8, 2);
            walk += b.ctrl_request(1, 100 + i * 8, 4);
        }
        assert_eq!(walk, 0);
        // A follow-up request sees identical backlog on both models.
        assert_eq!(
            a.home_request(TileId(9), 0, 2),
            b.home_request(TileId(9), 0, 2)
        );
        assert_eq!(a.ctrl_request(1, 0, 4), b.ctrl_request(1, 0, 4));
        // Busy controller: refused, state untouched.
        let mut m = model_on(Machine::tilepro64(), cfg);
        m.ctrl_request(2, 0, 1_000);
        assert!(!m.try_zero_delay_batch(None, 2, 2, 4, 10, 8, 50));
        assert_eq!(m.ctrl_request(2, 10, 4), 990);
        // Service exceeding the stride: the batch would queue — refused.
        let mut m = model_on(Machine::tilepro64(), cfg);
        assert!(!m.try_zero_delay_batch(Some(TileId(0)), 8, 0, 4, 0, 4, 2));
        // Link billing on: link servers are unmodelled here — refused.
        let mut m = model();
        assert!(!m.try_zero_delay_batch(None, 2, 0, 4, 0, 100, 10));
        // Contention disabled: trivially free either way.
        let mut off = model_on(
            Machine::tilepro64(),
            ContentionConfig {
                enabled: false,
                links: true,
                coherence: true,
            },
        );
        assert!(off.try_zero_delay_batch(Some(TileId(0)), 2, 0, 4, 0, 1, 1_000));
        assert_eq!(off.home_delay_cycles, 0);
    }

    #[test]
    fn link_servers_sized_by_machine() {
        let m = ContentionModel::new(
            ContentionConfig::default(),
            Arc::new(Machine::custom(4, 8, 2).unwrap()),
        );
        assert_eq!(m.link_requests.len(), 4 * 32);
        assert_eq!(m.link_reply_requests.len(), 4 * 32);
        assert_eq!(m.link_inval_requests.len(), 4 * 32);
    }
}
