//! Queueing contention for shared resources, modelled as exact
//! serialisation: each home tile's L2 port and each memory controller is a
//! single server with a deterministic per-request service time. A request
//! arriving at `now` starts at `max(now, server_free_at)`; the wait is the
//! queueing delay billed to the requester.
//!
//! The replay engine processes threads min-clock-first in small quanta, so
//! requests arrive approximately in simulated-time order and the
//! serialisation is near-exact. This is what makes the paper's disaster
//! case (non-localised + local homing: 63 threads hammering tile 0's L2
//! port) collapse to the port's service bandwidth, and what recreates the
//! Fig. 4 controller crossover.

use crate::arch::{TileId, NUM_CONTROLLERS, NUM_TILES};

#[derive(Clone, Copy, Debug)]
pub struct ContentionConfig {
    /// Globally disable queueing (ablation: `--no-contention`).
    pub enabled: bool,
}

impl Default for ContentionConfig {
    fn default() -> Self {
        ContentionConfig { enabled: true }
    }
}

#[derive(Clone, Copy, Default)]
struct Server {
    free_at: u64,
    /// Latest arrival time seen — the server's notion of "now". Quantum
    /// replay delivers some requests with stale timestamps (a thread's
    /// clock can lag another's by up to a batch span); those are slotted
    /// at the arrival frontier so they are billed only genuine backlog,
    /// never the idle gap another thread's batch left behind.
    last_arrival: u64,
}

impl Server {
    /// Serve one request arriving at `now`; returns queueing delay.
    ///
    /// Delays are self-limiting under min-clock replay: a thread billed a
    /// wait advances its clock, so its next arrival is later — steady-state
    /// per-request delay converges to (concurrent requesters × service),
    /// exactly the hardware's backpressure behaviour.
    fn request(&mut self, now: u64, service: u64) -> u64 {
        let arrival = now.max(self.last_arrival);
        self.last_arrival = arrival;
        let start = arrival.max(self.free_at);
        self.free_at = start + service;
        start - arrival
    }
}

pub struct ContentionModel {
    cfg: ContentionConfig,
    homes: Vec<Server>,
    ctrls: Vec<Server>,
    /// Total queueing cycles handed out (reporting).
    pub home_delay_cycles: u64,
    pub ctrl_delay_cycles: u64,
}

impl ContentionModel {
    pub fn new(cfg: ContentionConfig) -> Self {
        ContentionModel {
            cfg,
            homes: vec![Server::default(); NUM_TILES as usize],
            ctrls: vec![Server::default(); NUM_CONTROLLERS as usize],
            home_delay_cycles: 0,
            ctrl_delay_cycles: 0,
        }
    }

    /// One request to `home`'s L2 port at time `now`; returns queue delay.
    pub fn home_request(&mut self, home: TileId, now: u64, service: u64) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.homes[home.index()].request(now, service);
        self.home_delay_cycles += d;
        d
    }

    /// One line request to controller `c` at time `now`.
    pub fn ctrl_request(&mut self, c: u32, now: u64, service: u64) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let d = self.ctrls[c as usize].request(now, service);
        self.ctrl_delay_cycles += d;
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ContentionModel {
        ContentionModel::new(ContentionConfig::default())
    }

    #[test]
    fn uncontended_request_is_free() {
        let mut m = model();
        assert_eq!(m.home_request(TileId(0), 100, 2), 0);
        // Next request well after the first: still free.
        assert_eq!(m.home_request(TileId(0), 200, 2), 0);
    }

    #[test]
    fn back_to_back_requests_serialise() {
        let mut m = model();
        assert_eq!(m.home_request(TileId(0), 0, 2), 0);
        // Same instant: must wait for the 2-cycle service of the first.
        assert_eq!(m.home_request(TileId(0), 0, 2), 2);
        assert_eq!(m.home_request(TileId(0), 0, 2), 4);
    }

    #[test]
    fn hot_spot_collapses_to_service_bandwidth() {
        // 63 threads' worth of simultaneous traffic to one port: the k-th
        // request waits ~k*service — unbounded queueing, not a soft cap.
        let mut m = model();
        let mut last = 0;
        for _ in 0..1_000 {
            last = m.home_request(TileId(0), 0, 2);
        }
        assert!(last >= 1_900, "expected ~2k cycles of queue, got {last}");
    }

    #[test]
    fn queue_drains_over_time() {
        let mut m = model();
        for _ in 0..100 {
            m.home_request(TileId(0), 0, 2);
        }
        // Long after the burst: no residual delay.
        assert_eq!(m.home_request(TileId(0), 1_000_000, 2), 0);
    }

    #[test]
    fn resources_are_independent() {
        let mut m = model();
        for _ in 0..1_000 {
            m.home_request(TileId(0), 0, 2);
        }
        assert_eq!(m.home_request(TileId(1), 0, 2), 0);
        assert_eq!(m.ctrl_request(0, 0, 4), 0);
    }

    #[test]
    fn disabled_model_is_free() {
        let mut m = ContentionModel::new(ContentionConfig {
            enabled: false,
            ..Default::default()
        });
        for _ in 0..10_000 {
            assert_eq!(m.home_request(TileId(0), 0, 2), 0);
        }
        assert_eq!(m.home_delay_cycles, 0);
    }

    #[test]
    fn spreading_load_beats_hot_spot() {
        let mut hot = model();
        for i in 0..64_000u64 {
            hot.home_request(TileId(0), i / 4, 2);
        }
        let mut spread = model();
        for i in 0..64_000u64 {
            spread.home_request(TileId((i % 64) as u32), i / 4, 2);
        }
        assert!(
            hot.home_delay_cycles > spread.home_delay_cycles * 10,
            "hot {} vs spread {}",
            hot.home_delay_cycles,
            spread.home_delay_cycles
        );
    }

    #[test]
    fn partially_drained_queue_charges_remainder() {
        let mut m = model();
        for _ in 0..100 {
            m.home_request(TileId(0), 0, 2); // frontier at 200
        }
        assert_eq!(m.home_request(TileId(0), 150, 2), 50);
    }
}
