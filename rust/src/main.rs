//! `repro` — the leader CLI: run the paper's experiments on the simulated
//! TILEPro64 and exercise the PJRT request path.
//!
//! Subcommands:
//!
//! ```text
//! info                         chip + artifact summary
//! microbench [flags]           one micro-benchmark run (Alg. 2)
//! mergesort  [flags]           one merge-sort run (Alg. 3/4)
//! sort       [flags]           REAL sort via the AOT'd Pallas kernels
//! experiment <fig1|fig2|fig3|fig4|table1|all> [flags]
//! batch      <fig…|all|grid|gridscale|falseshare|placement|fabric|protocol|serve>
//!                              parallel sweeps over the worker pool
//! ```
//!
//! Common flags: `--size N` (supports k/m/ki/mi suffixes), `--threads N`,
//! `--reps N`, `--case 1..8`, `--seed S`, `--jobs N`, `--intra-jobs N`,
//! `--no-striping`,
//! `--json`, `--out DIR`. Target selection (`--machine`, `--fabric`,
//! `--protocol`, link billing) resolves through
//! [`tilesim::util::cli::TargetSpec`] so every subcommand shares one
//! conflict-error path.

use tilesim::arch::{CtrlPlacement, FabricSpec, MachineSpec, PartitionSpec};
use tilesim::coherence::ProtocolSpec;
use tilesim::coordinator::batch::{derive_seeds, BatchRunner, RunSpec, SweepSpec, Workload};
use tilesim::coordinator::{case, experiment, table1};
use tilesim::serve::{Admission, ArrivalSpec, BatchPolicy, ServeSweep, SizeMix};
use tilesim::util::cli::{parse_usize, Args, TargetSpec};
use tilesim::util::json::Json;
use tilesim::workloads::mergesort::Variant;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

const VALUE_FLAGS: &[&str] = &[
    "size",
    "threads",
    "reps",
    "case",
    "seed",
    "out",
    "sizes",
    "variant",
    "digit-bits",
    "jobs",
    "intra-jobs",
    "cases",
    "threads-list",
    "workload",
    "seeds",
    "machine",
    "machines",
    "fabric",
    "placements",
    "strengths",
    "protocol",
    "protocols",
    "rhos",
    "policies",
    "arrival",
    "requests",
    "queue-cap",
    "partitions",
    "admission",
];
const BOOL_FLAGS: &[&str] = &[
    "json",
    "no-striping",
    "no-cache",
    "localised",
    "help",
    "heatmap",
    "link-contention",
    "no-link-contention",
    "coherence-links",
    "no-coherence-links",
    "no-page-runs",
];

fn run(argv: &[String]) -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(argv, VALUE_FLAGS, BOOL_FLAGS).map_err(|e| {
        // A typo'd axis flag in grid mode (`--sizez`) dies here as a
        // generic unknown-flag error; attach the axes listing so the
        // sweep explains itself.
        let msg: Box<dyn std::error::Error> = if argv.iter().any(|a| a == "grid") {
            format!("{e}\n{GRID_AXES_HELP}").into()
        } else {
            Box::new(e)
        };
        msg
    })?;
    if args.flag("help") || args.positional().is_empty() {
        print_usage();
        return Ok(());
    }
    let seed = args.u64("seed", experiment::DEFAULT_SEED)?;
    let target = TargetSpec::from_args(&args)?;
    match args.positional()[0].as_str() {
        "info" => info(),
        "microbench" => {
            let c = case(args.usize("case", 8)? as u8);
            let mut spec = RunSpec::new(
                c.id,
                Workload::Microbench {
                    reps: args.usize("reps", 16)? as u32,
                },
                args.usize("size", 1_000_000)? as u64,
                args.usize("threads", 63)?,
                seed,
            )
            .on_machine(target.machine, target.link_contention, target.coherence_links)
            .with_fabric(target.fabric.clone())
            .with_protocol(target.protocol);
            if args.flag("no-page-runs") {
                spec = spec.without_page_runs();
            }
            spec.check_thread_capacity()?;
            emit_stats(
                &args,
                &run_label(&c.label(), &spec),
                &spec.execute_intra(args.usize("intra-jobs", 1)?),
                target.machine,
                target.fabric.as_ref(),
            );
            Ok(())
        }
        "mergesort" => {
            let c = case(args.usize("case", 8)? as u8);
            let variant = match args.get("variant") {
                None => c.mergesort_variant(),
                Some("non-localised") => Variant::NonLocalised,
                Some("intermediate") => Variant::NonLocalisedIntermediate,
                Some("localised") => Variant::Localised,
                Some(v) => return Err(format!("unknown variant {v}").into()),
            };
            let mut spec = RunSpec::new(
                c.id,
                Workload::Mergesort { variant },
                args.usize("size", 10_000_000)? as u64,
                args.usize("threads", 64)?,
                seed,
            )
            .with_striping(!args.flag("no-striping"))
            .on_machine(target.machine, target.link_contention, target.coherence_links)
            .with_fabric(target.fabric.clone())
            .with_protocol(target.protocol);
            if args.flag("no-cache") {
                spec = spec.without_caches();
            }
            if args.flag("no-page-runs") {
                spec = spec.without_page_runs();
            }
            spec.check_thread_capacity()?;
            emit_stats(
                &args,
                &run_label(&c.label(), &spec),
                &spec.execute_intra(args.usize("intra-jobs", 1)?),
                target.machine,
                target.fabric.as_ref(),
            );
            Ok(())
        }
        "radix" => {
            let c = case(args.usize("case", 8)? as u8);
            let mut spec = RunSpec::new(
                c.id,
                Workload::Radix {
                    digit_bits: args.usize("digit-bits", 8)? as u32,
                },
                args.usize("size", 1_000_000)? as u64,
                args.usize("threads", 63)?,
                seed,
            )
            .with_striping(!args.flag("no-striping"))
            .on_machine(target.machine, target.link_contention, target.coherence_links)
            .with_fabric(target.fabric.clone())
            .with_protocol(target.protocol);
            if args.flag("no-page-runs") {
                spec = spec.without_page_runs();
            }
            spec.check_thread_capacity()?;
            let label = run_label(&format!("radix sort — {}", c.label()), &spec);
            emit_stats(
                &args,
                &label,
                &spec.execute_intra(args.usize("intra-jobs", 1)?),
                target.machine,
                target.fabric.as_ref(),
            );
            Ok(())
        }
        "homing" => {
            if !target.protocol.is_default() {
                return Err(
                    "homing builds its engines directly and does not support --protocol".into(),
                );
            }
            let threads = args.usize("threads", 63)?;
            tilesim::coordinator::batch::check_thread_capacity(threads, target.machine)?;
            // Homing has no RunSpec, so the fabric fit-check runs here.
            target.machine.build_with_fabric(target.fabric.as_ref())?;
            let t = experiment::homing_classes(
                args.usize("size", 1_000_000)? as u64,
                threads,
                args.usize("reps", 16)? as u32,
                target.machine,
                target.fabric.as_ref(),
                target.link_contention,
            );
            println!("{}", t.render());
            Ok(())
        }
        "sort" => sort_real(&args),
        "experiment" => {
            let which = args
                .positional()
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            let specs: Vec<(String, SweepSpec)> = figure_specs(which, &args, seed)?
                .into_iter()
                .map(|(n, s)| {
                    (
                        n,
                        s.on_machine(
                            target.machine,
                            target.link_contention,
                            target.coherence_links,
                        )
                        .with_fabric(target.fabric.clone())
                        .with_protocol(target.protocol),
                    )
                })
                .collect();
            for (_, spec) in &specs {
                spec.check_thread_capacity()?;
            }
            let runner = BatchRunner::new(args.usize("jobs", 0)?)
                .with_intra_jobs(args.usize("intra-jobs", 1)?);
            let out = args.get("out").map(|s| s.to_string());
            for (name, spec) in &specs {
                let t = runner.table(spec);
                println!("{}", t.render());
                if let Some(dir) = &out {
                    t.save(dir, name)?;
                }
            }
            Ok(())
        }
        "batch" => batch_cmd(&args, seed, &target),
        other => {
            print_usage();
            Err(format!("unknown command '{other}'").into())
        }
    }
}

/// Resolve coherence-link billing (invalidation fan-out + reply paths):
/// follows the link-contention setting unless `--coherence-links` /
/// `--no-coherence-links` say otherwise. It rides on the link servers, so
/// it is inert while links are off.
fn coherence_links_arg(args: &Args, links: bool) -> bool {
    if args.flag("no-coherence-links") {
        false
    } else if args.flag("coherence-links") {
        true
    } else {
        links
    }
}

/// Label for a one-off run: the Table 1 case, plus the machine (and any
/// fabric or non-default protocol) when it is not the paper baseline.
fn run_label(case_label: &str, spec: &RunSpec) -> String {
    if spec.machine == MachineSpec::TilePro64
        && !spec.link_contention
        && spec.fabric.is_none()
        && spec.protocol.is_default()
    {
        case_label.to_string()
    } else {
        format!(
            "{case_label} | machine {}{}{}{}",
            spec.machine.label(),
            match &spec.fabric {
                Some(f) => format!(" fabric {}", f.label()),
                None => String::new(),
            },
            if spec.protocol.is_default() {
                String::new()
            } else {
                format!(" protocol {}", spec.protocol.label())
            },
            if spec.link_contention { " (link contention)" } else { "" }
        )
    }
}

/// Expand a figure selector into named sweep specs (shared by the
/// `experiment` and `batch` subcommands).
fn figure_specs(
    which: &str,
    args: &Args,
    seed: u64,
) -> Result<Vec<(String, SweepSpec)>, Box<dyn std::error::Error>> {
    let size = args.usize("size", 4_000_000)? as u64;
    let threads_all = [1usize, 2, 4, 8, 16, 32, 64];
    let mut specs: Vec<(String, SweepSpec)> = Vec::new();
    if which == "fig1" || which == "all" {
        specs.push((
            "fig1".into(),
            experiment::fig1_spec(
                args.usize("size", 1_000_000)? as u64,
                63,
                &[1, 2, 4, 8, 16, 32, 64],
                seed,
            ),
        ));
    }
    if which == "fig2" || which == "all" {
        specs.push(("fig2".into(), experiment::fig2_spec(size, &threads_all, seed)));
    }
    if which == "table1" || which == "all" {
        specs.push((
            "table1".into(),
            experiment::table1_spec(size, args.usize("threads", 64)?, seed),
        ));
    }
    if which == "fig3" || which == "all" {
        let sizes: Vec<u64> = match args.get("sizes") {
            Some(s) => {
                parse_list(s, |x| parse_usize(x).map(|v| v as u64)).ok_or("bad --sizes list")?
            }
            None => vec![1_000_000, 2_000_000, 4_000_000, 8_000_000],
        };
        specs.push(("fig3".into(), experiment::fig3_spec(&sizes, 64, seed)));
    }
    if which == "fig4" || which == "all" {
        specs.push(("fig4".into(), experiment::fig4_spec(size, &[16, 32, 64], seed)));
    }
    if specs.is_empty() {
        return Err(format!("unknown experiment '{which}'").into());
    }
    Ok(specs)
}

/// Reject flags that a ladder-driving sweep would silently ignore: these
/// sweeps build their own per-row machine/fabric grids, so a stray
/// `--machine` or `--fabric` is a conflict, reported as a one-line error
/// naming the flag.
fn reject_ladder_conflicts(
    args: &Args,
    sweep: &str,
    conflicts: &[(&str, &str)],
) -> Result<(), Box<dyn std::error::Error>> {
    for (flag, instead) in conflicts {
        if args.get(flag).is_some() {
            return Err(format!(
                "{sweep} sweeps its own ladder: --{flag} conflicts; {instead}"
            )
            .into());
        }
    }
    Ok(())
}

/// `repro batch <fig…|all|grid|gridscale|falseshare|placement|fabric|protocol|serve>`:
/// run sweeps through the worker pool and emit machine-readable results.
/// `--jobs N` shards across N host threads (0 = all cores); output is
/// byte-identical for every N.
fn batch_cmd(
    args: &Args,
    seed: u64,
    target: &TargetSpec,
) -> Result<(), Box<dyn std::error::Error>> {
    let (machine, links, coherence) =
        (target.machine, target.link_contention, target.coherence_links);
    let fabric = target.fabric.clone();
    let which = args
        .positional()
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    if which == "serve" {
        // The serve front-end has its own record shape (scenarios +
        // ladders + knee), not a SweepSpec — it branches off here.
        return serve_cmd(args, seed);
    }
    let runner = BatchRunner::new(args.usize("jobs", 0)?)
        .with_intra_jobs(args.usize("intra-jobs", 1)?);
    let out = args.get("out").map(|s| s.to_string());
    let specs = if which == "grid" {
        vec![(
            "grid".to_string(),
            grid_spec(args, seed)?
                .on_machine(machine, links, coherence)
                .with_fabric(fabric.clone())
                .with_protocol(target.protocol),
        )]
    } else if which == "gridscale" {
        // The grid-scaling sweep carries its own per-row machine ladder;
        // links are ON unless --no-link-contention (watching the mesh
        // saturate is the point).
        reject_ladder_conflicts(
            args,
            "gridscale",
            &[
                ("machine", "use --machines a,b,c"),
                ("fabric", "the ladder compares uniform fabrics"),
                ("placements", "use `batch placement` for placements"),
                ("strengths", "use `batch fabric` to sweep strengths"),
                ("protocol", "use `batch protocol` to sweep protocols"),
            ],
        )?;
        vec![("gridscale".to_string(), gridscale_spec(args, seed)?)]
    } else if which == "falseshare" {
        reject_ladder_conflicts(
            args,
            "falseshare",
            &[
                ("machine", "use --machines a,b,c"),
                ("fabric", "use `batch fabric` to sweep fabrics"),
                ("placements", "use `batch placement` for placements"),
                ("strengths", "use `batch fabric` to sweep strengths"),
                ("protocol", "use `batch protocol` to sweep protocols"),
            ],
        )?;
        vec![("falseshare".to_string(), falseshare_spec(args, seed)?)]
    } else if which == "placement" {
        reject_ladder_conflicts(
            args,
            "placement",
            &[
                ("machine", "use --machines a,b,c"),
                ("fabric", "use --placements edges,sides,corners,interior"),
                ("strengths", "use `batch fabric` to sweep strengths"),
                ("protocol", "use `batch protocol` to sweep protocols"),
            ],
        )?;
        vec![("placement".to_string(), placement_sweep(args, seed)?)]
    } else if which == "fabric" {
        reject_ladder_conflicts(
            args,
            "fabric",
            &[
                ("machine", "use --machines a,b,c"),
                ("fabric", "use --strengths 1,0.5,0.25"),
                ("placements", "use `batch placement` for placements"),
                ("protocol", "use `batch protocol` to sweep protocols"),
            ],
        )?;
        vec![("fabric".to_string(), fabric_sweep(args, seed)?)]
    } else if which == "protocol" {
        reject_ladder_conflicts(
            args,
            "protocol",
            &[
                ("machine", "use --machines a,b,c"),
                ("fabric", "use `batch fabric` to sweep fabrics"),
                ("placements", "use `batch placement` for placements"),
                ("strengths", "use `batch fabric` to sweep strengths"),
                ("protocol", "the lab already sweeps every protocol"),
            ],
        )?;
        vec![("protocol".to_string(), protocol_lab(args, seed)?)]
    } else {
        figure_specs(which, args, seed)?
            .into_iter()
            .map(|(n, s)| {
                (
                    n,
                    s.on_machine(machine, links, coherence)
                        .with_fabric(fabric.clone())
                        .with_protocol(target.protocol),
                )
            })
            .collect()
    };
    for (_, spec) in &specs {
        spec.check_thread_capacity()?;
    }
    eprintln!("batch: {} sweep(s) on {} worker(s)", specs.len(), runner.jobs());
    for (name, spec) in &specs {
        let store = runner.run(spec);
        // The protocol lab's record carries the winner/flip report next to
        // the sweep so `--json` consumers get both in one document.
        let record = if name == "protocol" {
            Json::obj(vec![
                ("sweep", store.to_json(spec)),
                ("report", experiment::protocol_report_json(spec, &store)),
            ])
        } else {
            store.to_json(spec)
        };
        if args.flag("json") {
            println!("{}", record.encode());
        } else {
            println!("{}", store.table(spec).render());
        }
        // These sweeps' headlines are derived ratios, not the seconds
        // table: falseshare reports coherence traffic, placement the
        // Fig. 4-style crossover, fabric the link-queue trajectory, the
        // protocol lab its per-row winners and cross-machine flips.
        match name.as_str() {
            "falseshare" => eprintln!("{}", experiment::falseshare_report(spec, &store)),
            "placement" => eprintln!("{}", experiment::placement_report(spec, &store)),
            "fabric" => eprintln!("{}", experiment::fabric_report(spec, &store)),
            "protocol" => eprintln!("{}", experiment::protocol_report(spec, &store)),
            _ => {}
        }
        if let Some(dir) = &out {
            store.table(spec).save(dir, name)?;
            let path = format!("{dir}/{name}_runs.json");
            std::fs::write(&path, record.encode())?;
            eprintln!("saved {path}");
        }
    }
    Ok(())
}

/// `repro batch serve`: the open-loop request front-end. Builds the
/// offered-load × batch-policy × machine × protocol scenario grid, shards
/// it over the worker pool, and reports per-request latency percentiles,
/// throughput-vs-offered-load ladders, and the saturation knee.
/// `--partitions` carves the chip into disjoint sub-grids serving
/// concurrent batches, `--admission sjf` takes smallest-first, and
/// `--size` accepts a percentage mix (`80%4ki,20%64ki`). `--json` emits
/// the full record (byte-identical at any `--jobs`/`--intra-jobs`).
fn serve_cmd(args: &Args, seed: u64) -> Result<(), Box<dyn std::error::Error>> {
    reject_ladder_conflicts(
        args,
        "serve",
        &[
            ("machine", "use --machines a,b,c"),
            ("fabric", "the serve grid compares uniform fabrics"),
            ("placements", "use `batch placement` for placements"),
            ("strengths", "use `batch fabric` to sweep strengths"),
            ("protocol", "use --protocols a,b,c"),
        ],
    )?;
    let machines = machines_arg(args, experiment::serve_machines)?;
    let protocols: Vec<ProtocolSpec> = match args.get("protocols") {
        None => vec![ProtocolSpec::default()],
        Some(s) => s
            .split(',')
            .map(|p| ProtocolSpec::parse(p.trim()))
            .collect::<Result<_, _>>()?,
    };
    let policies: Vec<BatchPolicy> = match args.get("policies") {
        None => experiment::serve_policies(),
        Some(s) => s
            .split(',')
            .map(|p| BatchPolicy::parse(p.trim()))
            .collect::<Result<_, _>>()?,
    };
    let rhos: Vec<f64> = match args.get("rhos") {
        None => experiment::serve_rhos(),
        Some(s) => parse_list(s, |x| {
            x.parse::<f64>().ok().filter(|r| *r > 0.0 && r.is_finite())
        })
        .ok_or("bad --rhos list: want positive offered-load fractions, e.g. 0.5,0.8,1.2")?,
    };
    let arrival = ArrivalSpec::parse(args.get("arrival").unwrap_or("poisson"))?;
    let case_id = args.usize("case", 8)? as u8;
    if !(1..=8).contains(&case_id) {
        return Err(format!("bad --case {case_id}: want a Table 1 id in 1..8").into());
    }
    let sizes = SizeMix::parse(args.get("size").unwrap_or("4096"))?;
    let admission = Admission::parse(args.get("admission").unwrap_or("fifo"))?;
    let partitions = PartitionSpec::parse(args.get("partitions").unwrap_or("whole"))?;
    if admission == Admission::Sjf && sizes.is_single() {
        return Err(
            "--admission sjf has nothing to reorder in a single-size stream; \
             pair it with a --size mix like 80%4ki,20%64ki"
                .into(),
        );
    }
    let threads = args.usize("threads", 16)?;
    let requests = args.u64("requests", 200)?;
    let queue_cap = args.usize("queue-cap", 64)?;
    let template = experiment::serve_template(case_id, sizes.mean_elems(), threads, seed);
    let sweep = ServeSweep::grid(
        &template,
        &machines,
        &protocols,
        &policies,
        arrival,
        &rhos,
        requests,
        queue_cap,
        args.flag("link-contention"),
        &partitions,
        admission,
        &sizes,
    );
    sweep.check()?;
    let runner = BatchRunner::new(args.usize("jobs", 0)?)
        .with_intra_jobs(args.usize("intra-jobs", 1)?);
    eprintln!(
        "serve: {} scenario(s) on {} worker(s)",
        sweep.scenarios.len(),
        runner.jobs()
    );
    let reports = sweep.run(&runner);
    let record = sweep.to_json(&reports);
    if args.flag("json") {
        println!("{}", record.encode());
    } else {
        println!("{}", sweep.table(&reports).render());
    }
    eprintln!("{}", sweep.report(&reports));
    if let Some(dir) = args.get("out") {
        sweep.table(&reports).save(dir, "serve")?;
        let path = format!("{dir}/serve_runs.json");
        std::fs::write(&path, record.encode())?;
        eprintln!("saved {path}");
    }
    Ok(())
}

/// Build the coherence-protocol lab (`repro batch protocol`): the rewrite
/// micro-benchmark, write ping-pong, and merge sort at every `--machines`
/// grid under every protocol, link + coherence billing always on.
fn protocol_lab(args: &Args, seed: u64) -> Result<SweepSpec, Box<dyn std::error::Error>> {
    let machines = machines_arg(args, experiment::protocol_machines)?;
    let elems = args.usize("size", 65_536)? as u64;
    let threads = args.usize("threads", 32)?;
    let reps = args.usize("reps", 4)? as u32;
    if threads == 0 || elems < 2 * threads as u64 || reps == 0 {
        return Err(format!(
            "bad protocol lab: need elems >= 2*threads and reps >= 1, got {elems} x {threads} \
             x {reps}"
        )
        .into());
    }
    let spec = experiment::protocol_spec(elems, threads, reps, reps, &machines, seed);
    spec.check_thread_capacity()?;
    Ok(spec)
}

/// Build the controller-placement sweep (`repro batch placement`): the
/// Fig. 4 striping grid per `--placements` strategy per `--machines` grid.
fn placement_sweep(args: &Args, seed: u64) -> Result<SweepSpec, Box<dyn std::error::Error>> {
    let machines = machines_arg(args, experiment::placement_machines)?;
    let placements: Vec<CtrlPlacement> = match args.get("placements") {
        None => experiment::placement_ladder(),
        Some(s) => s
            .split(',')
            .map(|p| CtrlPlacement::parse(p.trim()))
            .collect::<Result<_, _>>()?,
    };
    let elems = args.usize("size", 1_000_000)? as u64;
    let threads = args.usize("threads", 16)?;
    if threads == 0 || elems < 2 * threads as u64 {
        return Err(
            format!("bad placement: need elems >= 2*threads, got {elems} x {threads}").into(),
        );
    }
    let links = !args.flag("no-link-contention");
    let coherence = coherence_links_arg(args, links);
    let spec = experiment::placement_spec(
        elems, threads, &machines, &placements, seed, links, coherence,
    );
    spec.check_thread_capacity()?;
    Ok(spec)
}

/// Build the express-channel fabric sweep (`repro batch fabric`): the
/// write ping-pong at every `--machines` grid × `--strengths` factor.
fn fabric_sweep(args: &Args, seed: u64) -> Result<SweepSpec, Box<dyn std::error::Error>> {
    let machines = machines_arg(args, experiment::fabric_machines)?;
    let strengths: Vec<String> = match args.get("strengths") {
        None => experiment::fabric_strengths(),
        Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
    };
    if strengths.is_empty() {
        return Err("bad --strengths: need at least one factor".into());
    }
    let elems = args.usize("size", 65_536)? as u64;
    let threads = args.usize("threads", 32)?;
    let passes = args.usize("reps", 8)? as u32;
    if threads == 0 || elems < threads as u64 || passes == 0 {
        return Err(format!(
            "bad fabric sweep: need elems >= threads and reps >= 1, got {elems} x {threads} x {passes}"
        )
        .into());
    }
    let links = !args.flag("no-link-contention");
    let coherence = coherence_links_arg(args, links);
    let spec = experiment::fabric_sweep_spec(
        elems, threads, passes, &machines, &strengths, seed, links, coherence,
    )?;
    spec.check_thread_capacity()?;
    Ok(spec)
}

/// Parse a ladder sweep's `--machines` list, falling back to the sweep's
/// default ladder.
fn machines_arg(
    args: &Args,
    default: fn() -> Vec<MachineSpec>,
) -> Result<Vec<MachineSpec>, Box<dyn std::error::Error>> {
    match args.get("machines") {
        None => Ok(default()),
        Some(s) => Ok(s
            .split(',')
            .map(|m| MachineSpec::parse(m.trim()))
            .collect::<Result<_, _>>()?),
    }
}

/// Build the false-sharing sweep (`repro batch falseshare`): the write
/// ping-pong workload at every `--machines` grid (default 8×8 → 16×16),
/// non-localised vs localised, coherence-link billing always on.
fn falseshare_spec(args: &Args, seed: u64) -> Result<SweepSpec, Box<dyn std::error::Error>> {
    let machines = machines_arg(args, experiment::falseshare_machines)?;
    let elems = args.usize("size", 65_536)? as u64;
    let threads = args.usize("threads", 32)?;
    let passes = args.usize("reps", 8)? as u32;
    if threads == 0 || elems < threads as u64 || passes == 0 {
        return Err(format!(
            "bad falseshare: need elems >= threads and reps >= 1, got {elems} x {threads} x {passes}"
        )
        .into());
    }
    let spec = experiment::falseshare_spec(elems, threads, passes, &machines, seed);
    spec.check_thread_capacity()?;
    Ok(spec)
}

/// The grid axes `repro batch grid` understands, with their value syntax —
/// listed verbatim in every axis-related error so a typo'd sweep explains
/// itself instead of sending the user to the source. Axes are listed in
/// sorted (alphabetical) flag order, so the error text is stable as new
/// axes land and easy to scan for the one you typo'd.
const GRID_AXES_HELP: &str = "valid grid axes:\n  \
     --cases a,b,...        Table 1 case ids, each in 1..8 (default 1,3,8)\n  \
     --seeds K              number of derived seeds (default 1)\n  \
     --sizes a,b,...        element counts, k/m/g or ki/mi/gi suffixes (default 1m)\n  \
     --threads-list a,b,... thread counts >= 1 (default 64)\n  \
     --variant a,b,...      mergesort only: non-localised | intermediate | localised\n  \
     --workload NAME        mergesort | microbench | radix (default mergesort)";

/// Build the explicit case × elems × threads × variant × seed grid from
/// `--cases`, `--sizes`, `--threads-list`, `--workload`/`--variant`, and
/// `--seeds` (count derived from the base `--seed` via `util::rng`).
fn grid_spec(args: &Args, seed: u64) -> Result<SweepSpec, Box<dyn std::error::Error>> {
    let axis_err = |msg: String| -> Box<dyn std::error::Error> {
        format!("{msg}\n{GRID_AXES_HELP}").into()
    };
    let cases: Vec<u8> = parse_list(args.get("cases").unwrap_or("1,3,8"), |s| {
        s.parse::<u8>().ok().filter(|c| (1..=8).contains(c))
    })
    .ok_or_else(|| {
        axis_err(format!(
            "bad --cases list '{}' (want Table 1 ids in 1..8)",
            args.get("cases").unwrap_or("")
        ))
    })?;
    let sizes: Vec<u64> = parse_list(args.get("sizes").unwrap_or("1m"), |s| {
        parse_usize(s).map(|v| v as u64)
    })
    .ok_or_else(|| {
        axis_err(format!(
            "bad --sizes list '{}'",
            args.get("sizes").unwrap_or("")
        ))
    })?;
    let threads: Vec<usize> = parse_list(args.get("threads-list").unwrap_or("64"), parse_usize)
        .ok_or_else(|| {
            axis_err(format!(
                "bad --threads-list '{}'",
                args.get("threads-list").unwrap_or("")
            ))
        })?;
    let workloads: Vec<Workload> = match args.get("workload").unwrap_or("mergesort") {
        "mergesort" => {
            parse_list(args.get("variant").unwrap_or("non-localised,localised"), |v| {
                Some(Workload::Mergesort {
                    variant: match v {
                        "non-localised" => Variant::NonLocalised,
                        "intermediate" => Variant::NonLocalisedIntermediate,
                        "localised" => Variant::Localised,
                        _ => return None,
                    },
                })
            })
            .ok_or_else(|| {
                axis_err(format!(
                    "bad --variant list '{}'",
                    args.get("variant").unwrap_or("")
                ))
            })?
        }
        "microbench" => vec![Workload::Microbench {
            reps: args.usize("reps", 16)? as u32,
        }],
        "radix" => {
            let digit_bits = args.usize("digit-bits", 8)? as u32;
            if !(1..=16).contains(&digit_bits) {
                return Err(axis_err(format!(
                    "bad --digit-bits {digit_bits}: want 1..=16"
                )));
            }
            vec![Workload::Radix { digit_bits }]
        }
        w => return Err(axis_err(format!("unknown --workload '{w}'"))),
    };
    // Validate the grid up front: the trace builders assert on degenerate
    // inputs, and a panic inside a pool worker is a much worse error
    // message than a CLI Err.
    let max_threads = *threads.iter().max().expect("non-empty");
    let min_elems = *sizes.iter().min().expect("non-empty");
    if threads.contains(&0) {
        return Err("bad --threads-list: thread counts must be >= 1".into());
    }
    let min_required = if workloads
        .iter()
        .any(|w| matches!(w, Workload::Mergesort { .. }))
    {
        2 * max_threads as u64
    } else {
        max_threads as u64
    };
    if min_elems < min_required {
        return Err(format!(
            "bad grid: smallest --sizes value {min_elems} is below the minimum \
             {min_required} needed for {max_threads} threads"
        )
        .into());
    }
    let seeds = derive_seeds(seed, args.usize("seeds", 1)?.max(1));
    let title = format!(
        "Batch grid: {} cases x {} sizes x {} thread counts x {} workloads x {} seeds",
        cases.len(),
        sizes.len(),
        threads.len(),
        workloads.len(),
        seeds.len()
    );
    Ok(SweepSpec::grid(
        &title, &cases, &workloads, &sizes, &threads, &seeds,
    ))
}

/// Build the grid-scaling sweep (`repro batch gridscale`): the same merge
/// sort at every `--machines` grid (default 4×4 → 8×8 → 16×16), link
/// contention on unless `--no-link-contention`.
fn gridscale_spec(args: &Args, seed: u64) -> Result<SweepSpec, Box<dyn std::error::Error>> {
    let machines = machines_arg(args, experiment::grid_scaling_machines)?;
    let elems = args.usize("size", 1_000_000)? as u64;
    let threads = args.usize("threads", 16)?;
    if threads == 0 || elems < 2 * threads as u64 {
        return Err(
            format!("bad gridscale: need elems >= 2*threads, got {elems} x {threads}").into(),
        );
    }
    let links = !args.flag("no-link-contention");
    let coherence = coherence_links_arg(args, links);
    let spec = experiment::grid_scaling_spec(elems, threads, &machines, seed, links, coherence);
    spec.check_thread_capacity()?;
    Ok(spec)
}

fn parse_list<T>(s: &str, parse: impl Fn(&str) -> Option<T>) -> Option<Vec<T>> {
    let items: Option<Vec<T>> = s.split(',').map(|x| parse(x.trim())).collect();
    items.filter(|v| !v.is_empty())
}

fn info() -> Result<(), Box<dyn std::error::Error>> {
    println!(
        "tilesim: NUCA manycore simulator (default machine: TILEPro64 — 8x8 mesh, 64 tiles @ 860 MHz)"
    );
    println!("caches: 8 KB L1D (2-way), 64 KB L2 (4-way), 64 B lines, DDC home caches");
    println!("memory: 8 KB striping, 64 KB pages, first-touch homing under ucache_hash=none");
    println!("\nmachine presets (--machine):");
    for spec in [
        MachineSpec::TilePro64,
        MachineSpec::Epiphany16,
        MachineSpec::Nuca256,
    ] {
        let m = spec.build();
        println!(
            "  {:<12} {}x{} grid, {} tiles, {} controller(s)",
            m.name(),
            m.grid_w(),
            m.grid_h(),
            m.num_tiles(),
            m.num_controllers()
        );
    }
    println!("  WxH[:ctrls]  any grid up to 64x64, evenly spaced edge controllers");
    println!("\nTable 1 cases:");
    for c in table1() {
        println!("  {}", c.label());
    }
    let dir = tilesim::runtime::artifacts_dir();
    match tilesim::runtime::ArtifactSet::load(&dir) {
        Ok(set) => {
            println!("\nartifacts ({}): {}", dir.display(), set.names().join(", "));
        }
        Err(e) => println!("\nartifacts: not loaded ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn sort_real(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    use std::time::Instant;
    let n = args.usize("size", 1_000_000)?;
    let seed = args.u64("seed", 42)?;
    let dir = tilesim::runtime::artifacts_dir();
    let set = tilesim::runtime::ArtifactSet::load(&dir)?;
    let sorter = tilesim::runtime::ChunkedSorter::new(&set)?;
    let mut rng = tilesim::util::rng::Rng::new(seed);
    let data = rng.i32_vec(n);
    let t0 = Instant::now();
    let (sorted, metrics) = sorter.sort(&data)?;
    let dt = t0.elapsed().as_secs_f64();
    assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "output not sorted!");
    let mut check = data.clone();
    check.sort_unstable();
    assert_eq!(sorted, check, "output mismatch vs std sort");
    println!(
        "sorted {n} i32s via PJRT in {:.1} ms ({} dispatches, {} padded) — verified against std sort",
        dt * 1e3,
        metrics.dispatches,
        metrics.padded
    );
    Ok(())
}

fn emit_stats(
    args: &Args,
    label: &str,
    stats: &tilesim::sim::RunStats,
    machine: MachineSpec,
    fabric: Option<&FabricSpec>,
) {
    if args.flag("json") {
        println!("{}", stats.to_json().encode());
    } else {
        println!("{label}");
        println!("  {}", stats.summary());
        if let Some(why) = stats.intra_demoted {
            // Requested --intra-jobs N > 1 but the run stayed sequential;
            // say why instead of silently ignoring the flag.
            println!("  note: --intra-jobs ran sequentially — {why}");
        }
        if args.flag("heatmap") {
            // Render against the machine the run actually executed on —
            // fabric applied, so controller moves and service classes show.
            let m = machine
                .build_with_fabric(fabric)
                .expect("fabric validated at the CLI");
            let service_map = tilesim::metrics::fabric_map(&m);
            if !service_map.is_empty() {
                println!("{service_map}");
            }
            // The machine here is the one the run executed on, so a
            // MetricsError means a real bug — surface it, don't panic.
            match tilesim::metrics::home_heatmap(stats, &m) {
                Ok(map) => println!("{map}"),
                Err(e) => eprintln!("home heatmap unavailable: {e}"),
            }
            println!(
                "home-traffic concentration: {:.3} (0 = spread, 1 = one hot tile)",
                tilesim::metrics::home_concentration(stats)
            );
            match tilesim::metrics::link_heatmap(stats, &m) {
                Ok(links) if !links.is_empty() => println!("{links}"),
                Ok(_) => {}
                Err(e) => eprintln!("link heatmap unavailable: {e}"),
            }
            // Split the coherence traffic by class (the request class is
            // already shown by link_heatmap above; replies/invalidations
            // render only when coherence-link billing produced packets).
            for class in [
                tilesim::metrics::TrafficClass::Reply,
                tilesim::metrics::TrafficClass::Invalidation,
            ] {
                match tilesim::metrics::link_class_heatmap(stats, &m, class) {
                    Ok(map) if !map.is_empty() => println!("{map}"),
                    Ok(_) => {}
                    Err(e) => eprintln!("link class heatmap unavailable: {e}"),
                }
            }
        }
    }
}

fn print_usage() {
    println!(
        "usage: repro <info|microbench|mergesort|radix|homing|sort|experiment|batch> [flags]\n\
         experiments: repro experiment <fig1|fig2|fig3|fig4|table1|all> [--size N] [--out DIR]\n\
         batch:       repro batch <fig1|fig2|fig3|fig4|table1|all|grid|gridscale|falseshare\n\
                      |placement|fabric|protocol|serve> [--jobs N] [--out DIR] [--json]\n\
                      grid axes: --cases 1,3,8 --sizes 1m,4m --threads-list 16,64\n\
                      --workload mergesort|microbench|radix --variant a,b --seeds K\n\
                      gridscale:  --machines 4x4:2,tilepro64,nuca256 --size N --threads N\n\
                      falseshare: --machines tilepro64,nuca256 --size N --threads N --reps P\n\
                                  (write ping-pong; reports the coherence-traffic ratio)\n\
                      placement:  --machines tilepro64,16x16:4 --placements edges,sides,\n\
                                  corners,interior (Fig.4 striping crossover per placement)\n\
                      fabric:     --machines tilepro64,nuca256 --strengths 1,0.5,0.25\n\
                                  (express-channel ping-pong; link-queue trajectory)\n\
                      protocol:   --machines tilepro64,nuca256 --size N --threads N --reps P\n\
                                  (microbench/ping-pong/mergesort under every coherence\n\
                                  protocol; reports winners and cross-machine flips)\n\
                      serve:      --rhos 0.5,0.8,1.2 --policies immediate,batch8[@W]\n\
                                  --arrival poisson|bursty[@K] --requests N --queue-cap N\n\
                                  --machines a,b --protocols a,b --threads N\n\
                                  --size N | 80%4ki,20%64ki (request-size mix)\n\
                                  --partitions whole|P|PXxPY|rowsN|colsN|explicit:x,y,WxH;..\n\
                                  (spatial multi-server: one server per sub-grid)\n\
                                  --admission fifo|sjf (sjf needs a --size mix)\n\
                                  (open-loop request front-end; p50/p99/p999 latency,\n\
                                  throughput vs offered load, saturation knee per ladder;\n\
                                  rho = arrival rate x whole-chip single-request service)\n\
         machines: --machine tilepro64|epiphany16|nuca256|WxH[:ctrls] (default tilepro64)\n\
                   --fabric [machine:]ctrl=edges|sides|corners|interior|t+t[:base=N]\n\
                            [:express-row=Y@F][:express-col=X@F][:edge@F][:dir=D@F]\n\
                   --protocol write-invalidate|msi|mesi|moesi|write-update|opaque[@seed]\n\
                            (default write-invalidate — the paper's fused baseline path;\n\
                            a directory protocol defaults link+coherence billing ON)\n\
                   --link-contention / --no-link-contention (default: on off-baseline/fabric)\n\
                   --coherence-links / --no-coherence-links (default: follows link contention)\n\
         flags: --size N --threads N --reps N --case 1..8 --seed S --variant v\n\
                --digit-bits B --jobs N --intra-jobs N --no-striping --no-cache\n\
                --no-page-runs --heatmap --json --out DIR --sizes a,b,c\n\
         intra-jobs: host workers *inside* each replay (deterministic epoch\n\
                parallelism, every protocol included; stats are byte-identical\n\
                at any count). Budget rule: jobs x intra-jobs is clamped to\n\
                the host's cores.\n\
         no-page-runs: force the per-line reference walk instead of the\n\
                page-run fast path (same stats, slower — the CI oracle)."
    );
}
