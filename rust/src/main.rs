//! `repro` — the leader CLI: run the paper's experiments on the simulated
//! TILEPro64 and exercise the PJRT request path.
//!
//! Subcommands:
//!   info                         chip + artifact summary
//!   microbench [flags]           one micro-benchmark run (Alg. 2)
//!   mergesort  [flags]           one merge-sort run (Alg. 3/4)
//!   sort       [flags]           REAL sort via the AOT'd Pallas kernels
//!   experiment <fig1|fig2|fig3|fig4|table1|all> [flags]
//!
//! Common flags: --size N (supports k/m/ki/mi suffixes), --threads N,
//! --reps N, --case 1..8, --seed S, --no-striping, --json, --out DIR.

use tilesim::coordinator::{case, experiment, table1};
use tilesim::harness::SweepTable;
use tilesim::util::cli::{parse_usize, Args};
use tilesim::workloads::mergesort::Variant;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

const VALUE_FLAGS: &[&str] = &[
    "size", "threads", "reps", "case", "seed", "out", "sizes", "variant", "digit-bits",
];
const BOOL_FLAGS: &[&str] = &["json", "no-striping", "no-cache", "localised", "help", "heatmap"];

fn run(argv: &[String]) -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(argv, VALUE_FLAGS, BOOL_FLAGS)?;
    if args.flag("help") || args.positional().is_empty() {
        print_usage();
        return Ok(());
    }
    let seed = args.u64("seed", experiment::DEFAULT_SEED)?;
    match args.positional()[0].as_str() {
        "info" => info(),
        "microbench" => {
            let c = case(args.usize("case", 8)? as u8);
            let stats = experiment::run_microbench(
                &c,
                args.usize("size", 1_000_000)? as u64,
                args.usize("threads", 63)?,
                args.usize("reps", 16)? as u32,
                seed,
            );
            emit_stats(&args, &c.label(), &stats);
            Ok(())
        }
        "mergesort" => {
            let c = case(args.usize("case", 8)? as u8);
            let variant = match args.get("variant") {
                None => c.mergesort_variant(),
                Some("non-localised") => Variant::NonLocalised,
                Some("intermediate") => Variant::NonLocalisedIntermediate,
                Some("localised") => Variant::Localised,
                Some(v) => return Err(format!("unknown variant {v}").into()),
            };
            let mut engine_cfg = c.engine_config(!args.flag("no-striping"));
            if args.flag("no-cache") {
                engine_cfg = engine_cfg.without_caches();
            }
            let mut engine = tilesim::sim::Engine::new(engine_cfg);
            let program = tilesim::workloads::mergesort::build(
                &mut engine,
                &tilesim::workloads::mergesort::MergesortConfig {
                    elems: args.usize("size", 10_000_000)? as u64,
                    threads: args.usize("threads", 64)?,
                    variant,
                },
            );
            let mut sched = c.mapper.scheduler(seed);
            let stats = engine.run(&program, sched.as_mut())?;
            emit_stats(&args, &c.label(), &stats);
            Ok(())
        }
        "radix" => {
            let c = case(args.usize("case", 8)? as u8);
            let mut engine = tilesim::sim::Engine::new(c.engine_config(!args.flag("no-striping")));
            let program = tilesim::workloads::radix::build(
                &mut engine,
                &tilesim::workloads::radix::RadixConfig {
                    elems: args.usize("size", 1_000_000)? as u64,
                    threads: args.usize("threads", 63)?,
                    digit_bits: args.usize("digit-bits", 8)? as u32,
                    localised: c.localised,
                },
            );
            let mut sched = c.mapper.scheduler(seed);
            let stats = engine.run(&program, sched.as_mut())?;
            emit_stats(&args, &format!("radix sort — {}", c.label()), &stats);
            Ok(())
        }
        "homing" => {
            let t = experiment::homing_classes(
                args.usize("size", 1_000_000)? as u64,
                args.usize("threads", 63)?,
                args.usize("reps", 16)? as u32,
            );
            println!("{}", t.render());
            Ok(())
        }
        "sort" => sort_real(&args),
        "experiment" => {
            let which = args
                .positional()
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            let size = args.usize("size", 4_000_000)? as u64;
            let threads_all = [1usize, 2, 4, 8, 16, 32, 64];
            let out = args.get("out").map(|s| s.to_string());
            let mut tables: Vec<(String, SweepTable)> = Vec::new();
            if which == "fig1" || which == "all" {
                tables.push((
                    "fig1".into(),
                    experiment::fig1(
                        args.usize("size", 1_000_000)? as u64,
                        63,
                        &[1, 2, 4, 8, 16, 32, 64],
                        seed,
                    ),
                ));
            }
            if which == "fig2" || which == "all" {
                tables.push(("fig2".into(), experiment::fig2(size, &threads_all, seed)));
            }
            if which == "table1" || which == "all" {
                tables.push((
                    "table1".into(),
                    experiment::table1_times(size, args.usize("threads", 64)?, seed),
                ));
            }
            if which == "fig3" || which == "all" {
                let sizes: Vec<u64> = match args.get("sizes") {
                    Some(s) => s
                        .split(',')
                        .map(|x| parse_usize(x).map(|v| v as u64))
                        .collect::<Option<Vec<_>>>()
                        .ok_or("bad --sizes list")?,
                    None => vec![1_000_000, 2_000_000, 4_000_000, 8_000_000],
                };
                tables.push(("fig3".into(), experiment::fig3(&sizes, 64, seed)));
            }
            if which == "fig4" || which == "all" {
                tables.push((
                    "fig4".into(),
                    experiment::fig4(size, &[16, 32, 64], seed),
                ));
            }
            if tables.is_empty() {
                return Err(format!("unknown experiment '{which}'").into());
            }
            for (name, t) in &tables {
                println!("{}", t.render());
                if let Some(dir) = &out {
                    t.save(dir, name)?;
                }
            }
            Ok(())
        }
        other => {
            print_usage();
            Err(format!("unknown command '{other}'").into())
        }
    }
}

fn info() -> Result<(), Box<dyn std::error::Error>> {
    println!("tilesim: simulated TILEPro64 — 8x8 mesh, 64 tiles @ 860 MHz");
    println!("caches: 8 KB L1D (2-way), 64 KB L2 (4-way), 64 B lines, DDC home caches");
    println!("memory: 4 controllers, 8 KB striping, 64 KB pages, first-touch homing under ucache_hash=none");
    println!("\nTable 1 cases:");
    for c in table1() {
        println!("  {}", c.label());
    }
    let dir = tilesim::runtime::artifacts_dir();
    match tilesim::runtime::ArtifactSet::load(&dir) {
        Ok(set) => {
            println!("\nartifacts ({}): {}", dir.display(), set.names().join(", "));
        }
        Err(e) => println!("\nartifacts: not loaded ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn sort_real(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    use std::time::Instant;
    let n = args.usize("size", 1_000_000)?;
    let seed = args.u64("seed", 42)?;
    let dir = tilesim::runtime::artifacts_dir();
    let set = tilesim::runtime::ArtifactSet::load(&dir)?;
    let sorter = tilesim::runtime::ChunkedSorter::new(&set)?;
    let mut rng = tilesim::util::rng::Rng::new(seed);
    let data = rng.i32_vec(n);
    let t0 = Instant::now();
    let (sorted, metrics) = sorter.sort(&data)?;
    let dt = t0.elapsed().as_secs_f64();
    assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "output not sorted!");
    let mut check = data.clone();
    check.sort_unstable();
    assert_eq!(sorted, check, "output mismatch vs std sort");
    println!(
        "sorted {n} i32s via PJRT in {:.1} ms ({} dispatches, {} padded) — verified against std sort",
        dt * 1e3,
        metrics.dispatches,
        metrics.padded
    );
    Ok(())
}

fn emit_stats(args: &Args, label: &str, stats: &tilesim::sim::RunStats) {
    if args.flag("json") {
        println!("{}", stats.to_json().encode());
    } else {
        println!("{label}");
        println!("  {}", stats.summary());
        if args.flag("heatmap") {
            println!("{}", tilesim::metrics::home_heatmap(stats));
            println!(
                "home-traffic concentration: {:.3} (0 = spread, 1 = one hot tile)",
                tilesim::metrics::home_concentration(stats)
            );
        }
    }
}

fn print_usage() {
    println!(
        "usage: repro <info|microbench|mergesort|radix|homing|sort|experiment> [flags]\n\
         experiments: repro experiment <fig1|fig2|fig3|fig4|table1|all> [--size N] [--out DIR]\n\
         flags: --size N --threads N --reps N --case 1..8 --seed S --variant v\n\
                --digit-bits B --no-striping --no-cache --heatmap --json\n\
                --out DIR --sizes a,b,c"
    );
}
