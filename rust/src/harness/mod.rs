//! Benchmark harness (no criterion in the offline environment).
//!
//! Two kinds of measurement coexist in this repo's benches:
//!
//! 1. **Simulated time** — the paper's numbers: cycles reported by the NUCA
//!    engine, converted to seconds at 860 MHz. Deterministic, so a single
//!    run is exact; `SweepTable` renders these as the paper's tables/figures.
//! 2. **Wall-clock time** — how fast *our* simulator/runtime executes
//!    (EXPERIMENTS.md §Perf). `time_it` does warmup + repeated timing and
//!    reports min/mean/p50.

use std::time::Instant;

use crate::util::json::Json;

/// Wall-clock measurement of a closure.
pub struct Timing {
    pub iters: usize,
    pub min_s: f64,
    pub mean_s: f64,
    pub p50_s: f64,
}

impl Timing {
    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: min {:.3} ms, mean {:.3} ms, p50 {:.3} ms ({} iters)",
            self.min_s * 1e3,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.iters
        )
    }
}

/// Warmup then time `iters` runs of `f`.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    Timing {
        iters: n,
        min_s: samples[0],
        mean_s: samples.iter().sum::<f64>() / n as f64,
        p50_s: samples[n / 2],
    }
}

/// A table of sweep results, rendered like the paper's figures: one row per
/// x-value, one column per series.
pub struct SweepTable {
    pub title: String,
    pub x_label: String,
    pub series: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl SweepTable {
    pub fn new(title: &str, x_label: &str, series: Vec<String>) -> Self {
        SweepTable {
            title: title.to_string(),
            x_label: x_label.to_string(),
            series,
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, x: impl Into<String>, values: Vec<f64>) {
        assert_eq!(values.len(), self.series.len(), "row width mismatch");
        self.rows.push((x.into(), values));
    }

    /// Render a fixed-width text table (what the bench binaries print).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let w = 14usize;
        out.push_str(&format!("{:>w$}", self.x_label, w = w));
        for s in &self.series {
            out.push_str(&format!("{s:>w$}", w = w));
        }
        out.push('\n');
        for (x, vals) in &self.rows {
            out.push_str(&format!("{x:>w$}", w = w));
            for v in vals {
                if v.abs() >= 1000.0 {
                    out.push_str(&format!("{v:>w$.0}", w = w));
                } else {
                    out.push_str(&format!("{v:>w$.3}", w = w));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("x_label", Json::str(self.x_label.clone())),
            (
                "series",
                Json::arr(self.series.iter().map(|s| Json::str(s.clone()))),
            ),
            (
                "rows",
                Json::arr(self.rows.iter().map(|(x, vals)| {
                    Json::obj(vec![
                        ("x", Json::str(x.clone())),
                        ("values", Json::arr(vals.iter().map(|v| Json::num(*v)))),
                    ])
                })),
            ),
        ])
    }

    /// Write JSON next to the text output so EXPERIMENTS.md can cite files.
    pub fn save(&self, dir: &str, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{name}.json");
        std::fs::write(&path, self.to_json().encode())?;
        eprintln!("saved {path}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts_iters() {
        let mut n = 0usize;
        let t = time_it(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
        assert!(t.min_s <= t.mean_s + 1e-12);
    }

    #[test]
    fn sweep_table_renders_all_rows() {
        let mut t = SweepTable::new("T", "x", vec!["a".into(), "b".into()]);
        t.push_row("1", vec![1.0, 2.0]);
        t.push_row("2", vec![3.0, 4.0]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn sweep_table_rejects_ragged_rows() {
        let mut t = SweepTable::new("T", "x", vec!["a".into()]);
        t.push_row("1", vec![1.0, 2.0]);
    }

    #[test]
    fn sweep_table_json_round_trip() {
        let mut t = SweepTable::new("T", "x", vec!["a".into()]);
        t.push_row("1", vec![1.5]);
        let j = t.to_json();
        let parsed = crate::util::json::parse(&j.encode()).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str().unwrap(), "T");
    }
}
