//! Write ping-pong / false-sharing micro-benchmark (the `falseshare`
//! sweep's workload).
//!
//! A single shared array is initialised by `main` on tile 0. Each of `m`
//! worker threads then makes `passes` passes over its own `elems / m`
//! elements, *writing* each element individually:
//!
//! - **non-localised**: thread `i` owns the strided elements
//!   `j·m + i` — adjacent threads' elements share cache lines, so every
//!   line ping-pongs between writers: each store claims the line at the
//!   directory and invalidates the previous writer (plus ack), while the
//!   posted stores hammer the tile-0 home port. With coherence-link
//!   billing on, the invalidation fan-out and ack/reply routes occupy the
//!   mesh links — the traffic class that saturates large grids.
//! - **localised**: thread `i` allocates a private buffer (first-touch
//!   homed on its own tile under `ucache_hash=none`) and writes that
//!   instead — same element count, same bytes, zero sharing: stores stay
//!   in the local L2 and the mesh stays quiet.
//!
//! Both variants issue one 4-byte write op per element, so the simulated
//! line-event count is identical; only the *sharing pattern* differs.

use crate::arch::TileId;
use crate::mem::{AllocKind, VAddr};
use crate::sim::trace::{Loc, OpSource, SegmentGen, SegmentSource};
use crate::sim::{Engine, Program, TraceBuilder};

pub const ELEM_BYTES: u64 = 4;

/// Writes emitted per generator batch (bounds the resident trace window).
const WRITES_PER_FILL: u64 = 512;

#[derive(Clone, Copy, Debug)]
pub struct PingPongConfig {
    /// Total elements in the shared array (each thread owns `elems / m`).
    pub elems: u64,
    /// Worker threads.
    pub threads: usize,
    /// Write passes over the owned elements.
    pub passes: u32,
    /// Privatise the writes (the localisation fix) instead of striding
    /// through the shared array.
    pub localised: bool,
}

impl Default for PingPongConfig {
    fn default() -> Self {
        PingPongConfig {
            elems: 64 * 1024,
            threads: 32,
            passes: 8,
            localised: false,
        }
    }
}

/// Streaming generator for one worker: `passes × per` single-element
/// writes, chunked into bounded batches; the localised variant brackets
/// them with its private alloc/free.
struct ThreadGen {
    shared: VAddr,
    tid: u64,
    threads: u64,
    per: u64,
    passes: u32,
    localised: bool,
    slot: u32,
    pass: u32,
    j: u64,
    allocated: bool,
    freed: bool,
}

impl SegmentGen for ThreadGen {
    fn fill(&mut self, out: &mut TraceBuilder) -> bool {
        if self.localised && !self.allocated {
            out.alloc(self.slot, self.per * ELEM_BYTES, AllocKind::Heap);
            self.allocated = true;
            return true;
        }
        if self.pass >= self.passes {
            if self.localised && !self.freed {
                out.free(self.slot);
                self.freed = true;
                return true;
            }
            return false;
        }
        let mut emitted = 0u64;
        while emitted < WRITES_PER_FILL && self.pass < self.passes {
            if self.j == self.per {
                self.j = 0;
                self.pass += 1;
                continue;
            }
            let loc = if self.localised {
                Loc::Slot {
                    slot: self.slot,
                    offset: self.j * ELEM_BYTES,
                }
            } else {
                Loc::Abs(
                    self.shared
                        .offset((self.j * self.threads + self.tid) * ELEM_BYTES),
                )
            };
            out.write(loc, ELEM_BYTES);
            self.j += 1;
            emitted += 1;
        }
        true
    }

    fn rewind(&mut self) {
        self.pass = 0;
        self.j = 0;
        self.allocated = false;
        self.freed = false;
    }
}

/// Build the ping-pong program against `engine`'s memory system. The
/// shared array is touched by `main` on tile 0 first, so under
/// `ucache_hash=none` every page homes there — the non-localised variant's
/// hot spot.
pub fn build(engine: &mut Engine, cfg: &PingPongConfig) -> Program {
    assert!(
        cfg.threads >= 1 && cfg.elems >= cfg.threads as u64,
        "need at least one element per thread"
    );
    let shared = engine.prealloc_touched(TileId(0), cfg.elems * ELEM_BYTES);
    let per = cfg.elems / cfg.threads as u64;
    let mut sources: Vec<Box<dyn OpSource>> = Vec::with_capacity(cfg.threads);
    for i in 0..cfg.threads {
        sources.push(SegmentSource::boxed(ThreadGen {
            shared: shared.addr,
            tid: i as u64,
            threads: cfg.threads as u64,
            per,
            passes: cfg.passes,
            localised: cfg.localised,
            slot: i as u32,
            pass: 0,
            j: 0,
            allocated: false,
            freed: false,
        }));
    }
    Program::new(sources, cfg.threads as u32, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::EngineConfig;

    fn engine(links: bool) -> Engine {
        let mut cfg = EngineConfig::tilepro64(MemConfig {
            hash_policy: HashPolicy::None,
            striping: true,
        });
        cfg.contention.links = links;
        Engine::new(cfg)
    }

    fn small(localised: bool) -> PingPongConfig {
        PingPongConfig {
            elems: 4096,
            threads: 8,
            passes: 4,
            localised,
        }
    }

    #[test]
    fn program_validates_and_streams_repeatably() {
        for localised in [false, true] {
            let mut e = engine(false);
            let mut p = build(&mut e, &small(localised));
            p.validate().unwrap();
            let first = p.record();
            let second = p.record();
            assert_eq!(first, second, "stream must rewind identically");
            // per = 512 elements × 4 passes (+ alloc/free when localised).
            let extra = if localised { 2 } else { 0 };
            assert_eq!(first[0].len(), 512 * 4 + extra);
        }
    }

    #[test]
    fn non_localised_ping_pongs_invalidations() {
        let mut e = engine(false);
        let mut p = build(&mut e, &small(false));
        let shared = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        let mut e = engine(false);
        let mut p = build(&mut e, &small(true));
        let local = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert!(
            shared.invalidations > 10 * local.invalidations.max(1),
            "false sharing must dominate invalidations: shared {} vs local {}",
            shared.invalidations,
            local.invalidations
        );
        assert!(
            local.makespan_cycles < shared.makespan_cycles,
            "privatised writes must win: {} vs {}",
            local.makespan_cycles,
            shared.makespan_cycles
        );
    }

    #[test]
    fn coherence_billing_surfaces_the_ping_pong_on_links() {
        let mut e = engine(true);
        let mut p = build(&mut e, &small(false));
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert!(stats.invalidation_link_cycles > 0, "fan-out must queue");
        assert!(stats.link_inval_requests.iter().sum::<u64>() > 0);
    }
}
