//! The paper's workloads as trace generators: the micro-benchmark
//! (Algorithm 2), parallel merge sort (Algorithms 3/4), the radix-sort
//! comparison baseline (related work \[3\]), additional array kernels
//! expressed through the generic localisation API, and the write
//! ping-pong / false-sharing benchmark behind the `falseshare` coherence
//! sweep ([`pingpong`]).

pub mod array_kernels;
pub mod mergesort;
pub mod microbench;
pub mod pingpong;
pub mod radix;

pub use array_kernels::{HistogramKernel, MapKernel, ReduceKernel, StencilKernel};
