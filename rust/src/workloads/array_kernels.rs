//! Additional memory-bound array computations expressed through the
//! generic localisation API (coordinator::localise) — the paper claims the
//! technique "can be generally applied to any parallelisable array
//! computation, where each part of the array is accessed multiple times";
//! these kernels back that claim (and the custom-workload example).
//!
//! Every kernel is *step-emitting*: one pass/sweep per step, so the
//! streaming trace pipeline buffers a single pass regardless of how many
//! passes the configuration asks for.

use crate::coordinator::localise::ChunkKernel;
use crate::sim::{Loc, TraceBuilder};

/// Element-wise map applied `passes` times in place (e.g. iterative
/// normalisation): read + write the chunk each pass.
pub struct MapKernel {
    pub passes: u32,
    /// ALU cycles per element per pass.
    pub flops_per_elem: u64,
}

impl ChunkKernel for MapKernel {
    fn steps(&self) -> u32 {
        self.passes
    }
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _thread: usize, _s: u32) {
        let elems = bytes / 4;
        t.read(chunk, bytes)
            .compute(elems * self.flops_per_elem)
            .write(chunk, bytes);
    }
    fn name(&self) -> &'static str {
        "map"
    }
}

/// Iterative 3-point stencil (Jacobi-style smoothing): per sweep, read the
/// chunk plus one halo line on each side, write the chunk.
pub struct StencilKernel {
    pub sweeps: u32,
}

impl ChunkKernel for StencilKernel {
    fn steps(&self) -> u32 {
        self.sweeps
    }
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _thread: usize, _s: u32) {
        let elems = bytes / 4;
        // Halo exchange: one extra cache line each side (left halo only
        // at offset 0 — the Loc abstraction clamps at region start, so
        // model both halos as one extra line read each).
        t.read(chunk, bytes.min(64)); // left halo line
        t.read(chunk, bytes)
            .compute(elems * 3)
            .write(chunk, bytes);
    }
    fn name(&self) -> &'static str {
        "stencil3"
    }
}

/// Histogram: `passes` counting scans over the chunk (reads only), with a
/// per-element bucket update cost.
pub struct HistogramKernel {
    pub passes: u32,
}

impl ChunkKernel for HistogramKernel {
    fn steps(&self) -> u32 {
        self.passes
    }
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _thread: usize, _s: u32) {
        let elems = bytes / 4;
        t.read(chunk, bytes).compute(elems * 2);
    }
    fn name(&self) -> &'static str {
        "histogram"
    }
}

/// Sum-reduction with `passes` full scans (e.g. multi-statistic pass:
/// sum, min/max, variance…), one compute cycle per element per pass.
pub struct ReduceKernel {
    pub passes: u32,
}

impl ChunkKernel for ReduceKernel {
    fn steps(&self) -> u32 {
        self.passes
    }
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _thread: usize, _s: u32) {
        let elems = bytes / 4;
        t.read(chunk, bytes).compute(elems);
    }
    fn name(&self) -> &'static str {
        "reduce"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TileId;
    use crate::coordinator::localise::{build_program, LocaliseConfig, ELEM_BYTES};
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::{Engine, EngineConfig, RunStats};
    use std::rc::Rc;

    fn run(kernel: Rc<dyn ChunkKernel>, localised: bool, policy: HashPolicy) -> RunStats {
        let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }));
        let elems = 1u64 << 15;
        let input = e.prealloc_touched(TileId(0), elems * ELEM_BYTES);
        let mut p = build_program(
            &input,
            elems,
            &LocaliseConfig {
                threads: 8,
                localised,
            },
            kernel,
        );
        e.run(&mut p, &mut StaticMapper::new()).unwrap()
    }

    #[test]
    fn all_kernels_run_both_styles() {
        let kernels: Vec<Rc<dyn ChunkKernel>> = vec![
            Rc::new(MapKernel { passes: 4, flops_per_elem: 1 }),
            Rc::new(StencilKernel { sweeps: 4 }),
            Rc::new(HistogramKernel { passes: 4 }),
            Rc::new(ReduceKernel { passes: 4 }),
        ];
        for k in &kernels {
            for localised in [false, true] {
                let s = run(k.clone(), localised, HashPolicy::None);
                assert!(s.makespan_cycles > 0, "{} localised={localised}", k.name());
            }
        }
    }

    #[test]
    fn localisation_helps_every_kernel_under_local_homing() {
        // The generality claim: all four kernels speed up with Algorithm 1
        // under ucache_hash=none (reads of tile-0-stranded data become
        // local L2 hits).
        let kernels: Vec<Rc<dyn ChunkKernel>> = vec![
            Rc::new(MapKernel { passes: 8, flops_per_elem: 1 }),
            Rc::new(StencilKernel { sweeps: 8 }),
            Rc::new(HistogramKernel { passes: 8 }),
            Rc::new(ReduceKernel { passes: 8 }),
        ];
        for k in &kernels {
            let conv = run(k.clone(), false, HashPolicy::None);
            let loc = run(k.clone(), true, HashPolicy::None);
            assert!(
                loc.makespan_cycles < conv.makespan_cycles,
                "{}: localised {} vs conventional {}",
                k.name(),
                loc.makespan_cycles,
                conv.makespan_cycles
            );
        }
    }

    #[test]
    fn read_only_kernels_do_not_invalidate() {
        let s = run(
            Rc::new(HistogramKernel { passes: 3 }),
            false,
            HashPolicy::AllButStack,
        );
        assert_eq!(s.invalidations, 0, "pure reads must not invalidate");
    }
}
