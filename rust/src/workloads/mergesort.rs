//! Parallel recursive merge sort (Algorithms 3 & 4, Figs. 2–4).
//!
//! The trace generator mirrors the paper's OpenMP nested-sections recursion
//! exactly: `threads` splits into `threads/2` + `threads − threads/2`
//! subtrees over the two array halves; each leaf runs the serial merge sort
//! on its chunk; each internal node's merge runs on the subtree's leftmost
//! thread after joining the right subtree (a Wait on its completion event).
//!
//! The recursion is *streamed*: each thread's trace is an explicit-stack
//! generator (`ThreadGen`) that walks the recursion tree on demand and
//! emits only that thread's ops, one recursion step per batch. Every
//! generator performs the identical tree walk (so the program-global slot
//! and event numbering agrees across threads) but skips the serial-sort
//! descent of leaves it does not own — the walk is O(threads) bookkeeping
//! plus the thread's own ops, and resident memory is one recursion stack,
//! not an N·log N op vector.
//!
//! Three variants:
//! - `NonLocalised` — Algorithm 3: leaves sort slices of the shared
//!   `array0` using slices of the shared `scratch0`, merges write `scratch0`
//!   then memcpy back into `array0`.
//! - `NonLocalisedIntermediate` — Algorithm 3 + only the *intermediate
//!   step* of Algorithm 4 (§5.2): merges allocate a fresh `ext_scr` and skip
//!   the copy-back; leaf sorting is unchanged.
//! - `Localised` — Algorithm 4: each leaf copies its chunk into a fresh
//!   local array (`input_cpy`, re-homed by first touch) and sorts there
//!   with a local scratch; merges allocate `ext_scr` and free their inputs
//!   at the next level (Algorithm 1 step 5).

use crate::arch::TileId;
use crate::mem::AllocKind;
use crate::sim::trace::{OpSource, SegmentGen, SegmentSource};
use crate::sim::{Engine, Loc, Program, TraceBuilder};

pub const ELEM_BYTES: u64 = 4;

/// Below this many elements a subrange fits L1 many times over: emit one
/// materialisation pass plus the equivalent ALU+L1 work.
const SERIAL_BASE: u64 = 256;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    NonLocalised,
    NonLocalisedIntermediate,
    Localised,
}

impl Variant {
    pub fn label(self) -> &'static str {
        match self {
            Variant::NonLocalised => "non-localised",
            Variant::NonLocalisedIntermediate => "non-localised+interm",
            Variant::Localised => "localised",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MergesortConfig {
    /// Elements to sort (paper: up to 100 M).
    pub elems: u64,
    /// Leaf threads (paper: 1..64).
    pub threads: usize,
    pub variant: Variant,
}

/// Result location of a subtree sort (where the sorted run lives).
#[derive(Clone, Copy)]
struct SortedRun {
    loc: Loc,
    /// Slot to free once consumed by the parent merge (localised variants).
    slot: Option<u32>,
    bytes: u64,
}

/// Everything the recursion needs, identical across all thread generators.
#[derive(Clone, Copy)]
struct GenParams {
    array0: Loc,
    scratch0: Loc,
    variant: Variant,
    compute_per_elem: u64,
    threads: usize,
    elems: u64,
}

/// One frame of the explicit recursion stack.
#[derive(Clone, Copy)]
enum Task {
    /// `mergesort_parallel_omp` over `[off, off+elems)`, `th` threads
    /// starting at `lo`.
    Node { lo: usize, th: usize, off: u64, elems: u64 },
    /// Join + merge of a node once both subtrees produced their runs.
    Join { lo: usize, lt: usize, off: u64 },
    /// `mergesort_serial` recursion (owned leaves only).
    SerialSort { input: Loc, scratch: Loc, elems: u64 },
    /// Merge step of the serial recursion.
    SerialMerge { input: Loc, scratch: Loc, elems: u64 },
    /// Free a leaf's local scratch after its serial sort (Localised).
    FreeScr { slot: u32 },
}

/// Explicit-stack streaming generator for one thread's trace.
struct ThreadGen {
    tid: usize,
    p: GenParams,
    tasks: Vec<Task>,
    /// Value stack of subtree results (parallels the recursion's returns).
    runs: Vec<SortedRun>,
    next_slot: u32,
    next_event: u32,
}

impl ThreadGen {
    fn new(tid: usize, p: GenParams) -> Self {
        ThreadGen {
            tid,
            p,
            tasks: vec![Task::Node {
                lo: 0,
                th: p.threads,
                off: 0,
                elems: p.elems,
            }],
            runs: Vec::new(),
            next_slot: 0,
            next_event: 0,
        }
    }

    fn slot(&mut self) -> u32 {
        let s = self.next_slot;
        self.next_slot += 1;
        s
    }

    fn event(&mut self) -> u32 {
        let e = self.next_event;
        self.next_event += 1;
        e
    }

    /// Leaf of the parallel recursion: serial-sort this thread's chunk.
    /// Slot numbering advances in every generator; ops (and the serial
    /// descent) are emitted only by the owning thread's generator.
    fn leaf(&mut self, leaf_tid: usize, off: u64, elems: u64, out: &mut TraceBuilder) {
        let bytes = elems * ELEM_BYTES;
        match self.p.variant {
            Variant::NonLocalised | Variant::NonLocalisedIntermediate => {
                let input = self.p.array0.offset(off * ELEM_BYTES);
                let scratch = self.p.scratch0.offset(off * ELEM_BYTES);
                if self.tid == leaf_tid {
                    self.tasks.push(Task::SerialSort {
                        input,
                        scratch,
                        elems,
                    });
                }
                self.runs.push(SortedRun {
                    loc: input,
                    slot: None,
                    bytes,
                });
            }
            Variant::Localised => {
                // int* input_cpy = new int[size]; memcpy(...); sort it
                // against a local scratch; return input_cpy (freed by the
                // parent merge).
                let cpy = self.slot();
                let scr = self.slot();
                let cpy_loc = Loc::Slot { slot: cpy, offset: 0 };
                let scr_loc = Loc::Slot { slot: scr, offset: 0 };
                if self.tid == leaf_tid {
                    let input = self.p.array0.offset(off * ELEM_BYTES);
                    out.alloc(cpy, bytes, AllocKind::Heap)
                        .copy(input, cpy_loc, bytes)
                        .alloc(scr, bytes, AllocKind::Heap);
                    // LIFO: the serial sort runs first, then the scratch is
                    // freed.
                    self.tasks.push(Task::FreeScr { slot: scr });
                    self.tasks.push(Task::SerialSort {
                        input: cpy_loc,
                        scratch: scr_loc,
                        elems,
                    });
                }
                self.runs.push(SortedRun {
                    loc: cpy_loc,
                    slot: Some(cpy),
                    bytes,
                });
            }
        }
    }

    /// One step of the *depth-first* serial merge-sort recursion over
    /// `[input, input+elems)` with `scratch` as the auxiliary array
    /// (`mergesort_serial`). Depth-first order is what gives real merge
    /// sort its cache behaviour — small subranges are sorted completely
    /// (staying resident in whatever cache level can hold them) before the
    /// recursion moves on; only the top levels stream the whole chunk.
    fn serial_sort(&mut self, input: Loc, scratch: Loc, elems: u64, out: &mut TraceBuilder) {
        let bytes = elems * ELEM_BYTES;
        if elems <= SERIAL_BASE {
            let levels = 64 - (elems.max(2) - 1).leading_zeros() as u64; // ceil(log2)
            out.read(input, bytes)
                .write(scratch, bytes)
                .copy(scratch, input, bytes)
                // Remaining levels run inside L1: 1 compare + ~2cy L1 access
                // per element per level.
                .compute(levels * elems * (self.p.compute_per_elem + 2));
            return;
        }
        let half = elems / 2;
        // LIFO: left half, right half, then the merge of the two.
        self.tasks.push(Task::SerialMerge {
            input,
            scratch,
            elems,
        });
        self.tasks.push(Task::SerialSort {
            input: input.offset(half * ELEM_BYTES),
            scratch: scratch.offset(half * ELEM_BYTES),
            elems: elems - half,
        });
        self.tasks.push(Task::SerialSort {
            input,
            scratch,
            elems: half,
        });
    }

    /// Merge two sorted runs on thread `lo` (`merge`). `off` is the
    /// element offset of the pair in the original array (for the shared
    /// scratch slice of the non-localised variant).
    fn merge(&mut self, lo: usize, off: u64, left: SortedRun, right: SortedRun, out: &mut TraceBuilder) {
        let bytes = left.bytes + right.bytes;
        let elems = bytes / ELEM_BYTES;
        let compute = elems * self.p.compute_per_elem;
        match self.p.variant {
            Variant::NonLocalised => {
                // merge(): read both halves, write the shared scratch, then
                // memcpy(input1, scratch, ...) back.
                if self.tid == lo {
                    let scratch = self.p.scratch0.offset(off * ELEM_BYTES);
                    out.read(left.loc, left.bytes)
                        .read(right.loc, right.bytes)
                        .compute(compute)
                        .write(scratch, bytes)
                        .copy(scratch, left.loc, bytes);
                }
                self.runs.push(SortedRun {
                    loc: left.loc,
                    slot: None,
                    bytes,
                });
            }
            Variant::NonLocalisedIntermediate | Variant::Localised => {
                // Intermediate step: int* ext_scr = new int[sz1+sz2]; merge
                // into it; free the previous level's arrays; return ext_scr.
                let ext = self.slot();
                let ext_loc = Loc::Slot { slot: ext, offset: 0 };
                if self.tid == lo {
                    out.alloc(ext, bytes, AllocKind::Heap)
                        .read(left.loc, left.bytes)
                        .read(right.loc, right.bytes)
                        .compute(compute)
                        .write(ext_loc, bytes);
                    if let Some(s) = left.slot {
                        out.free(s);
                    }
                    if let Some(s) = right.slot {
                        out.free(s);
                    }
                }
                self.runs.push(SortedRun {
                    loc: ext_loc,
                    slot: Some(ext),
                    bytes,
                });
            }
        }
    }

    fn step(&mut self, task: Task, out: &mut TraceBuilder) {
        match task {
            Task::Node { lo, th, off, elems } => {
                if th == 1 {
                    self.leaf(lo, off, elems, out);
                    return;
                }
                let lt = th / 2;
                let le = elems / 2;
                // LIFO: left subtree, right subtree, then the join+merge.
                self.tasks.push(Task::Join { lo, lt, off });
                self.tasks.push(Task::Node {
                    lo: lo + lt,
                    th: th - lt,
                    off: off + le,
                    elems: elems - le,
                });
                self.tasks.push(Task::Node {
                    lo,
                    th: lt,
                    off,
                    elems: le,
                });
            }
            Task::Join { lo, lt, off } => {
                let right = self.runs.pop().expect("right subtree run");
                let left = self.runs.pop().expect("left subtree run");
                // Right subtree's leftmost thread signals its completion;
                // the node's leftmost thread joins it, then merges.
                let ev = self.event();
                if self.tid == lo + lt {
                    out.signal(ev);
                }
                if self.tid == lo {
                    out.wait(ev);
                }
                self.merge(lo, off, left, right, out);
            }
            Task::SerialSort {
                input,
                scratch,
                elems,
            } => self.serial_sort(input, scratch, elems, out),
            Task::SerialMerge {
                input,
                scratch,
                elems,
            } => {
                // Merge the two sorted halves: read both, write scratch,
                // copy back.
                let bytes = elems * ELEM_BYTES;
                out.read(input, bytes)
                    .compute(elems * self.p.compute_per_elem)
                    .write(scratch, bytes)
                    .copy(scratch, input, bytes);
            }
            Task::FreeScr { slot } => {
                out.free(slot);
            }
        }
    }
}

impl SegmentGen for ThreadGen {
    fn fill(&mut self, out: &mut TraceBuilder) -> bool {
        while let Some(task) = self.tasks.pop() {
            self.step(task, out);
            if !out.ops().is_empty() {
                return true;
            }
        }
        false
    }

    fn rewind(&mut self) {
        self.tasks = vec![Task::Node {
            lo: 0,
            th: self.p.threads,
            off: 0,
            elems: self.p.elems,
        }];
        self.runs.clear();
        self.next_slot = 0;
        self.next_event = 0;
    }
}

/// Walk the recursion once with a generator that owns no thread: counts
/// slots/events without emitting (or descending into) any serial sort.
fn slot_event_totals(p: GenParams) -> (u32, u32) {
    let mut g = ThreadGen::new(usize::MAX, p);
    let mut scratch = TraceBuilder::new();
    while g.fill(&mut scratch) {
        scratch.clear();
    }
    (g.next_slot, g.next_event)
}

/// Build the merge-sort program against `engine`'s memory system.
///
/// `array0` is initialised by `main` on tile 0 (first-touch strands it
/// there under `ucache_hash=none`); `scratch0` is allocated but *not*
/// initialised, so its pages fault in from whichever worker touches them
/// first — exactly the Linux behaviour the paper's cases inherit.
pub fn build(engine: &mut Engine, cfg: &MergesortConfig) -> Program {
    assert!(cfg.threads >= 1);
    assert!(cfg.elems >= cfg.threads as u64 * 2, "chunks must be non-trivial");
    let bytes = cfg.elems * ELEM_BYTES;
    let array0 = engine.prealloc_touched(TileId(0), bytes);
    let scratch0 = engine.prealloc(TileId(0), bytes);

    let p = GenParams {
        array0: Loc::Abs(array0.addr),
        scratch0: Loc::Abs(scratch0.addr),
        variant: cfg.variant,
        compute_per_elem: engine.params().compute_per_elem,
        threads: cfg.threads,
        elems: cfg.elems,
    };
    // main(): the caller takes ownership of the result; the localised
    // variants' final ext_scr stays live (swapped into array0 in the C++).
    let (slots, events) = slot_event_totals(p);
    let sources: Vec<Box<dyn OpSource>> = (0..cfg.threads)
        .map(|tid| SegmentSource::boxed(ThreadGen::new(tid, p)))
        .collect();
    Program::new(sources, slots.max(1), events.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::EngineConfig;

    fn engine(policy: HashPolicy) -> Engine {
        Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }))
    }

    fn run(policy: HashPolicy, variant: Variant, elems: u64, threads: usize) -> crate::sim::RunStats {
        let mut e = engine(policy);
        let mut p = build(
            &mut e,
            &MergesortConfig {
                elems,
                threads,
                variant,
            },
        );
        p.validate().unwrap();
        e.run(&mut p, &mut StaticMapper::new()).unwrap()
    }

    #[test]
    fn all_variants_build_and_run() {
        for v in [
            Variant::NonLocalised,
            Variant::NonLocalisedIntermediate,
            Variant::Localised,
        ] {
            let stats = run(HashPolicy::AllButStack, v, 1 << 14, 4);
            assert!(stats.makespan_cycles > 0, "{v:?}");
            assert!(stats.line_accesses > 0, "{v:?}");
        }
    }

    #[test]
    fn odd_thread_counts_supported() {
        for t in [1usize, 3, 5, 7] {
            let stats = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 12, t);
            assert!(stats.makespan_cycles > 0, "threads={t}");
        }
    }

    #[test]
    fn streams_replay_identically_after_reset() {
        for v in [
            Variant::NonLocalised,
            Variant::NonLocalisedIntermediate,
            Variant::Localised,
        ] {
            let mut e = engine(HashPolicy::None);
            let mut p = build(
                &mut e,
                &MergesortConfig {
                    elems: 1 << 12,
                    threads: 6,
                    variant: v,
                },
            );
            let a = p.record();
            let b = p.record();
            assert_eq!(a, b, "{v:?}");
            assert!(a.iter().all(|t| !t.is_empty()), "{v:?}: every thread works");
        }
    }

    #[test]
    fn parallel_is_faster_than_serial() {
        let s1 = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 16, 1);
        let s16 = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 16, 16);
        assert!(
            s16.makespan_cycles * 2 < s1.makespan_cycles,
            "16 threads {} vs 1 thread {}",
            s16.makespan_cycles,
            s1.makespan_cycles
        );
    }

    #[test]
    fn localised_wins_under_local_homing() {
        // Fig. 2's Case 8 vs Case 4 essence (both static-mapped, hash=none).
        let non_loc = run(HashPolicy::None, Variant::NonLocalised, 1 << 16, 16);
        let loc = run(HashPolicy::None, Variant::Localised, 1 << 16, 16);
        assert!(
            loc.makespan_cycles < non_loc.makespan_cycles,
            "localised {} vs non-localised {}",
            loc.makespan_cycles,
            non_loc.makespan_cycles
        );
    }

    #[test]
    fn localised_competitive_under_hash() {
        let non_loc = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 16, 16);
        let loc = run(HashPolicy::AllButStack, Variant::Localised, 1 << 16, 16);
        let ratio = loc.makespan_cycles as f64 / non_loc.makespan_cycles as f64;
        assert!(ratio < 1.25, "localised under hash ratio {ratio}");
    }

    #[test]
    fn intermediate_step_reduces_traffic() {
        // Skipping the copy-back must strictly reduce line accesses.
        let plain = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 15, 8);
        let interm = run(
            HashPolicy::AllButStack,
            Variant::NonLocalisedIntermediate,
            1 << 15,
            8,
        );
        assert!(interm.line_accesses < plain.line_accesses);
    }

    #[test]
    fn localised_frees_everything_but_root() {
        let stats = run(HashPolicy::None, Variant::Localised, 1 << 14, 8);
        // 8 leaves × (cpy + scr) + 7 merges × ext = 23 allocs (+2 preallocs);
        // everything freed except the root ext_scr.
        assert_eq!(stats.allocs, 2 + 23);
        assert_eq!(stats.frees, 22);
    }

    #[test]
    fn reduction_tree_events_match_internal_nodes() {
        let mut e = engine(HashPolicy::None);
        let p = build(
            &mut e,
            &MergesortConfig {
                elems: 1 << 12,
                threads: 8,
                variant: Variant::NonLocalised,
            },
        );
        assert_eq!(p.num_events, 7, "8 leaves -> 7 internal joins");
    }
}
