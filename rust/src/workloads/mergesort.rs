//! Parallel recursive merge sort (Algorithms 3 & 4, Figs. 2–4).
//!
//! The trace generator mirrors the paper's OpenMP nested-sections recursion
//! exactly: `threads` splits into `threads/2` + `threads − threads/2`
//! subtrees over the two array halves; each leaf runs the serial merge sort
//! on its chunk; each internal node's merge runs on the subtree's leftmost
//! thread after joining the right subtree (a Wait on its completion event).
//!
//! Three variants:
//! - `NonLocalised` — Algorithm 3: leaves sort slices of the shared
//!   `array0` using slices of the shared `scratch0`, merges write `scratch0`
//!   then memcpy back into `array0`.
//! - `NonLocalisedIntermediate` — Algorithm 3 + only the *intermediate
//!   step* of Algorithm 4 (§5.2): merges allocate a fresh `ext_scr` and skip
//!   the copy-back; leaf sorting is unchanged.
//! - `Localised` — Algorithm 4: each leaf copies its chunk into a fresh
//!   local array (`input_cpy`, re-homed by first touch) and sorts there
//!   with a local scratch; merges allocate `ext_scr` and free their inputs
//!   at the next level (Algorithm 1 step 5).

use crate::arch::{LatencyParams, TileId};
use crate::mem::AllocKind;
use crate::sim::{Engine, Loc, Program, TraceBuilder};

pub const ELEM_BYTES: u64 = 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    NonLocalised,
    NonLocalisedIntermediate,
    Localised,
}

impl Variant {
    pub fn label(self) -> &'static str {
        match self {
            Variant::NonLocalised => "non-localised",
            Variant::NonLocalisedIntermediate => "non-localised+interm",
            Variant::Localised => "localised",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MergesortConfig {
    /// Elements to sort (paper: up to 100 M).
    pub elems: u64,
    /// Leaf threads (paper: 1..64).
    pub threads: usize,
    pub variant: Variant,
}

/// Result location of a subtree sort (where the sorted run lives).
#[derive(Clone, Copy)]
struct SortedRun {
    loc: Loc,
    /// Slot to free once consumed by the parent merge (localised variants).
    slot: Option<u32>,
    bytes: u64,
}

struct Builder<'a> {
    traces: Vec<TraceBuilder>,
    next_slot: u32,
    next_event: u32,
    array0: Loc,
    scratch0: Loc,
    variant: Variant,
    compute_per_elem: u64,
    _engine: &'a Engine,
}

impl<'a> Builder<'a> {
    fn slot(&mut self) -> u32 {
        let s = self.next_slot;
        self.next_slot += 1;
        s
    }

    fn event(&mut self) -> u32 {
        let e = self.next_event;
        self.next_event += 1;
        e
    }

    /// Emit the *depth-first* serial merge-sort recursion over
    /// `[input, input+elems)` with `scratch` as the auxiliary array
    /// (`mergesort_serial`). Depth-first order is what gives real merge
    /// sort its cache behaviour — small subranges are sorted completely
    /// (staying resident in whatever cache level can hold them) before the
    /// recursion moves on; only the top levels stream the whole chunk.
    /// Below `SERIAL_BASE` elements the subrange fits L1 many times over,
    /// so we emit one materialisation pass plus the equivalent ALU+L1 work.
    fn serial_sort(&mut self, tid: usize, input: Loc, scratch: Loc, elems: u64) {
        const SERIAL_BASE: u64 = 256;
        let bytes = elems * ELEM_BYTES;
        if elems <= SERIAL_BASE {
            let levels = 64 - (elems.max(2) - 1).leading_zeros() as u64; // ceil(log2)
            let t = &mut self.traces[tid];
            t.read(input, bytes)
                .write(scratch, bytes)
                .copy(scratch, input, bytes)
                // Remaining levels run inside L1: 1 compare + ~2cy L1 access
                // per element per level.
                .compute(levels * elems * (self.compute_per_elem + 2));
            return;
        }
        let half = elems / 2;
        self.serial_sort(tid, input, scratch, half);
        self.serial_sort(
            tid,
            input.offset(half * ELEM_BYTES),
            scratch.offset(half * ELEM_BYTES),
            elems - half,
        );
        // Merge the two sorted halves: read both, write scratch, copy back.
        let t = &mut self.traces[tid];
        t.read(input, bytes)
            .compute(elems * self.compute_per_elem)
            .write(scratch, bytes)
            .copy(scratch, input, bytes);
    }

    /// Leaf of the parallel recursion: serial-sort this thread's chunk.
    fn leaf(&mut self, tid: usize, off: u64, elems: u64) -> SortedRun {
        let bytes = elems * ELEM_BYTES;
        match self.variant {
            Variant::NonLocalised | Variant::NonLocalisedIntermediate => {
                let input = self.array0.offset(off * ELEM_BYTES);
                let scratch = self.scratch0.offset(off * ELEM_BYTES);
                self.serial_sort(tid, input, scratch, elems);
                SortedRun {
                    loc: input,
                    slot: None,
                    bytes,
                }
            }
            Variant::Localised => {
                // int* input_cpy = new int[size]; memcpy(...); sort it
                // against a local scratch; return input_cpy (freed by the
                // parent merge).
                let cpy = self.slot();
                let scr = self.slot();
                let input = self.array0.offset(off * ELEM_BYTES);
                let cpy_loc = Loc::Slot { slot: cpy, offset: 0 };
                let scr_loc = Loc::Slot { slot: scr, offset: 0 };
                {
                    let t = &mut self.traces[tid];
                    t.alloc(cpy, bytes, AllocKind::Heap)
                        .copy(input, cpy_loc, bytes)
                        .alloc(scr, bytes, AllocKind::Heap);
                }
                self.serial_sort(tid, cpy_loc, scr_loc, elems);
                self.traces[tid].free(scr);
                SortedRun {
                    loc: cpy_loc,
                    slot: Some(cpy),
                    bytes,
                }
            }
        }
    }

    /// Merge two sorted runs on thread `tid` (`merge`). `off` is the
    /// element offset of the pair in the original array (for the shared
    /// scratch slice of the non-localised variant).
    fn merge(&mut self, tid: usize, off: u64, left: SortedRun, right: SortedRun) -> SortedRun {
        let bytes = left.bytes + right.bytes;
        let elems = bytes / ELEM_BYTES;
        let compute = elems * self.compute_per_elem;
        match self.variant {
            Variant::NonLocalised => {
                // merge(): read both halves, write the shared scratch, then
                // memcpy(input1, scratch, ...) back.
                let scratch = self.scratch0.offset(off * ELEM_BYTES);
                let dst = left.loc;
                let t = &mut self.traces[tid];
                t.read(left.loc, left.bytes)
                    .read(right.loc, right.bytes)
                    .compute(compute)
                    .write(scratch, bytes)
                    .copy(scratch, dst, bytes);
                SortedRun {
                    loc: dst,
                    slot: None,
                    bytes,
                }
            }
            Variant::NonLocalisedIntermediate | Variant::Localised => {
                // Intermediate step: int* ext_scr = new int[sz1+sz2]; merge
                // into it; free the previous level's arrays; return ext_scr.
                let ext = self.slot();
                let ext_loc = Loc::Slot { slot: ext, offset: 0 };
                let t = &mut self.traces[tid];
                t.alloc(ext, bytes, AllocKind::Heap)
                    .read(left.loc, left.bytes)
                    .read(right.loc, right.bytes)
                    .compute(compute)
                    .write(ext_loc, bytes);
                if let Some(s) = left.slot {
                    t.free(s);
                }
                if let Some(s) = right.slot {
                    t.free(s);
                }
                SortedRun {
                    loc: ext_loc,
                    slot: Some(ext),
                    bytes,
                }
            }
        }
    }

    /// `mergesort_parallel_omp`: recurse over `[off, off+elems)` with
    /// `threads` leaf threads starting at `tid_lo`. Returns the sorted run.
    fn node(&mut self, tid_lo: usize, threads: usize, off: u64, elems: u64) -> SortedRun {
        if threads == 1 {
            return self.leaf(tid_lo, off, elems);
        }
        let lt = threads / 2;
        let rt = threads - lt;
        let le = elems / 2;
        let re = elems - le;
        // Left subtree continues on this thread; right subtree's leftmost
        // thread signals its completion.
        let left = self.node(tid_lo, lt, off, le);
        let right = self.node(tid_lo + lt, rt, off + le, re);
        let ev = self.event();
        self.traces[tid_lo + lt].signal(ev);
        self.traces[tid_lo].wait(ev);
        self.merge(tid_lo, off, left, right)
    }
}

/// Build the merge-sort program against `engine`'s memory system.
///
/// `array0` is initialised by `main` on tile 0 (first-touch strands it
/// there under `ucache_hash=none`); `scratch0` is allocated but *not*
/// initialised, so its pages fault in from whichever worker touches them
/// first — exactly the Linux behaviour the paper's cases inherit.
pub fn build(engine: &mut Engine, cfg: &MergesortConfig) -> Program {
    assert!(cfg.threads >= 1);
    assert!(cfg.elems >= cfg.threads as u64 * 2, "chunks must be non-trivial");
    let bytes = cfg.elems * ELEM_BYTES;
    let array0 = engine.prealloc_touched(TileId(0), bytes);
    let scratch0 = engine.prealloc(TileId(0), bytes);

    let params: &LatencyParams = engine.params();
    let mut b = Builder {
        traces: vec![TraceBuilder::new(); cfg.threads],
        next_slot: 0,
        next_event: 0,
        array0: Loc::Abs(array0.addr),
        scratch0: Loc::Abs(scratch0.addr),
        variant: cfg.variant,
        compute_per_elem: params.compute_per_elem,
        _engine: engine,
    };
    let root = b.node(0, cfg.threads, 0, cfg.elems);
    // main(): the caller takes ownership of the result; the localised
    // variants' final ext_scr stays live (swapped into array0 in the C++).
    let _ = root;
    let (slots, events) = (b.next_slot, b.next_event);
    Program::from_builders(b.traces, slots.max(1), events.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::EngineConfig;

    fn engine(policy: HashPolicy) -> Engine {
        Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }))
    }

    fn run(policy: HashPolicy, variant: Variant, elems: u64, threads: usize) -> crate::sim::RunStats {
        let mut e = engine(policy);
        let p = build(
            &mut e,
            &MergesortConfig {
                elems,
                threads,
                variant,
            },
        );
        p.validate().unwrap();
        e.run(&p, &mut StaticMapper::new()).unwrap()
    }

    #[test]
    fn all_variants_build_and_run() {
        for v in [
            Variant::NonLocalised,
            Variant::NonLocalisedIntermediate,
            Variant::Localised,
        ] {
            let stats = run(HashPolicy::AllButStack, v, 1 << 14, 4);
            assert!(stats.makespan_cycles > 0, "{v:?}");
            assert!(stats.line_accesses > 0, "{v:?}");
        }
    }

    #[test]
    fn odd_thread_counts_supported() {
        for t in [1usize, 3, 5, 7] {
            let stats = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 12, t);
            assert!(stats.makespan_cycles > 0, "threads={t}");
        }
    }

    #[test]
    fn parallel_is_faster_than_serial() {
        let s1 = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 16, 1);
        let s16 = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 16, 16);
        assert!(
            s16.makespan_cycles * 2 < s1.makespan_cycles,
            "16 threads {} vs 1 thread {}",
            s16.makespan_cycles,
            s1.makespan_cycles
        );
    }

    #[test]
    fn localised_wins_under_local_homing() {
        // Fig. 2's Case 8 vs Case 4 essence (both static-mapped, hash=none).
        let non_loc = run(HashPolicy::None, Variant::NonLocalised, 1 << 16, 16);
        let loc = run(HashPolicy::None, Variant::Localised, 1 << 16, 16);
        assert!(
            loc.makespan_cycles < non_loc.makespan_cycles,
            "localised {} vs non-localised {}",
            loc.makespan_cycles,
            non_loc.makespan_cycles
        );
    }

    #[test]
    fn localised_competitive_under_hash() {
        let non_loc = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 16, 16);
        let loc = run(HashPolicy::AllButStack, Variant::Localised, 1 << 16, 16);
        let ratio = loc.makespan_cycles as f64 / non_loc.makespan_cycles as f64;
        assert!(ratio < 1.25, "localised under hash ratio {ratio}");
    }

    #[test]
    fn intermediate_step_reduces_traffic() {
        // Skipping the copy-back must strictly reduce line accesses.
        let plain = run(HashPolicy::AllButStack, Variant::NonLocalised, 1 << 15, 8);
        let interm = run(
            HashPolicy::AllButStack,
            Variant::NonLocalisedIntermediate,
            1 << 15,
            8,
        );
        assert!(interm.line_accesses < plain.line_accesses);
    }

    #[test]
    fn localised_frees_everything_but_root() {
        let stats = run(HashPolicy::None, Variant::Localised, 1 << 14, 8);
        // 8 leaves × (cpy + scr) + 7 merges × ext = 23 allocs (+2 preallocs);
        // everything freed except the root ext_scr.
        assert_eq!(stats.allocs, 2 + 23);
        assert_eq!(stats.frees, 22);
    }

    #[test]
    fn reduction_tree_events_match_internal_nodes() {
        let mut e = engine(HashPolicy::None);
        let p = build(
            &mut e,
            &MergesortConfig {
                elems: 1 << 12,
                threads: 8,
                variant: Variant::NonLocalised,
            },
        );
        assert_eq!(p.num_events, 7, "8 leaves -> 7 internal joins");
    }
}
