//! Parallel LSD radix sort — the comparison baseline from the paper's
//! related work ([3] Morari et al., "Efficient sorting on the Tilera
//! manycore architecture"), which sorted with radix partitioning and
//! fine-grained TMC tuning. Implementing it lets the benches compare the
//! localisation technique across *algorithms*, not just within merge sort.
//!
//! Structure per digit pass (radix 2^B, W/B passes over W-bit keys):
//!   1. count: each thread histograms its chunk (sequential read);
//!   2. prefix: thread 0 combines the 64×2^B histogram matrix (barrier);
//!   3. scatter: each thread re-reads its chunk and writes each key to its
//!      destination bucket — *scattered* writes across the whole output
//!      array, the access pattern that stresses homing policies very
//!      differently from merge sort's sequential streams.
//!
//! The localised variant applies Algorithm 1 to the chunk (copy → local
//! reads), but the scatter writes remain global by nature — which is why
//! radix gains less from localisation than merge sort, matching [3]'s
//! preference for explicit fine-grained control.

use crate::arch::TileId;
use crate::mem::AllocKind;
use crate::sim::{Engine, Loc, Program, TraceBuilder};
use crate::workloads::microbench::part_bounds;

pub const ELEM_BYTES: u64 = 4;

#[derive(Clone, Copy, Debug)]
pub struct RadixConfig {
    pub elems: u64,
    pub threads: usize,
    /// Bits per digit (2^bits buckets); 8 → 4 passes over u32 keys.
    pub digit_bits: u32,
    /// Apply Algorithm 1 to the read side of each pass.
    pub localised: bool,
}

impl Default for RadixConfig {
    fn default() -> Self {
        RadixConfig {
            elems: 1_000_000,
            threads: 63,
            digit_bits: 8,
            localised: false,
        }
    }
}

/// Build the radix-sort program. Uses a double buffer (src/dst swap per
/// pass), both allocated by main; histograms live on each thread's stack.
pub fn build(engine: &mut Engine, cfg: &RadixConfig) -> Program {
    assert!(cfg.threads >= 1 && cfg.elems >= cfg.threads as u64);
    assert!(cfg.digit_bits >= 1 && cfg.digit_bits <= 16);
    let bytes = cfg.elems * ELEM_BYTES;
    let src = engine.prealloc_touched(TileId(0), bytes);
    let dst = engine.prealloc(TileId(0), bytes);
    let passes = 32u32.div_ceil(cfg.digit_bits);
    let buckets = 1u64 << cfg.digit_bits;
    let hist_bytes = buckets * 8;

    let mut builders = vec![TraceBuilder::new(); cfg.threads];
    let mut next_event = 0u32;
    // Per-thread chunk bounds.
    let bounds: Vec<(u64, u64)> = (0..cfg.threads)
        .map(|i| part_bounds(cfg.elems, cfg.threads, i))
        .collect();
    // Slots: per thread per pass a local copy (localised only) + one stack
    // histogram slot per thread.
    let mut next_slot = 0u32;
    let hist_slots: Vec<u32> = (0..cfg.threads)
        .map(|i| {
            let s = next_slot;
            next_slot += 1;
            builders[i].alloc(s, hist_bytes, AllocKind::Stack);
            s
        })
        .collect();

    let mut cur_src = Loc::Abs(src.addr);
    let mut cur_dst = Loc::Abs(dst.addr);
    for pass in 0..passes {
        // --- count phase -------------------------------------------------
        for (i, b) in builders.iter_mut().enumerate() {
            let (start, end) = bounds[i];
            let part_bytes = (end - start) * ELEM_BYTES;
            let chunk = cur_src.offset(start * ELEM_BYTES);
            let hist = Loc::Slot { slot: hist_slots[i], offset: 0 };
            let read_from = if cfg.localised {
                let s = next_slot;
                next_slot += 1;
                let local = Loc::Slot { slot: s, offset: 0 };
                b.alloc(s, part_bytes, AllocKind::Heap);
                b.copy(chunk, local, part_bytes);
                local
            } else {
                chunk
            };
            b.read(read_from, part_bytes)
                .compute(end - start) // digit extraction + count
                .write(hist, hist_bytes);
            // signal count done
            b.signal(next_event + i as u32);
            if cfg.localised {
                // keep the local copy alive for the scatter phase: the slot
                // id is recoverable as next_slot-1; free after scatter.
            }
        }
        let count_base = next_event;
        next_event += cfg.threads as u32;

        // --- prefix phase on thread 0 ------------------------------------
        {
            let b = &mut builders[0];
            for i in 0..cfg.threads as u32 {
                b.wait(count_base + i);
            }
            // Read all histograms (remote stacks!) and compute global
            // prefix sums — a small all-to-one step.
            for i in 0..cfg.threads {
                b.read(Loc::Slot { slot: hist_slots[i], offset: 0 }, hist_bytes);
            }
            b.compute(buckets * cfg.threads as u64);
            for i in 0..cfg.threads {
                b.write(Loc::Slot { slot: hist_slots[i], offset: 0 }, hist_bytes);
            }
            b.signal(next_event);
        }
        let prefix_done = next_event;
        next_event += 1;

        // --- scatter phase ------------------------------------------------
        for (i, b) in builders.iter_mut().enumerate() {
            let (start, end) = bounds[i];
            let part_bytes = (end - start) * ELEM_BYTES;
            b.wait(prefix_done);
            let read_from = if cfg.localised {
                // The copy made in the count phase for this pass.
                let slot = hist_slots.len() as u32
                    + (pass * cfg.threads as u32)
                    + i as u32;
                Loc::Slot { slot, offset: 0 }
            } else {
                cur_src.offset(start * ELEM_BYTES)
            };
            // Re-read the chunk; writes scatter over the whole destination:
            // model as strided writes across the full dst range (one line
            // per ~buckets/elems stride is unmodelable exactly; bill the
            // same byte volume spread as `buckets` separate run writes).
            b.read(read_from, part_bytes).compute(2 * (end - start));
            let runs = buckets.min(end - start).max(1);
            let run_bytes = (part_bytes / runs).max(ELEM_BYTES);
            let span = cfg.elems * ELEM_BYTES - run_bytes;
            for r in 0..runs {
                // Spread the write targets across dst deterministically.
                let off = (r * 0x9E37_79B9 + pass as u64 * 0x85EB_CA6B) % (span / ELEM_BYTES + 1)
                    * ELEM_BYTES;
                b.write(cur_dst.offset(off), run_bytes);
            }
            if cfg.localised {
                let slot = hist_slots.len() as u32
                    + (pass * cfg.threads as u32)
                    + i as u32;
                b.free(slot);
            }
            b.signal(next_event + i as u32);
        }
        let scatter_base = next_event;
        next_event += cfg.threads as u32;
        // Barrier: everyone waits for all scatters before the next pass
        // (thread 0 aggregates; others wait on thread 0's echo).
        {
            let b = &mut builders[0];
            for i in 1..cfg.threads as u32 {
                b.wait(scatter_base + i);
            }
            b.signal(next_event);
        }
        let pass_done = next_event;
        next_event += 1;
        for b in builders.iter_mut().skip(1) {
            b.wait(pass_done);
        }
        std::mem::swap(&mut cur_src, &mut cur_dst);
    }
    for (i, b) in builders.iter_mut().enumerate() {
        b.free(hist_slots[i]);
    }
    Program::from_builders(builders, next_slot, next_event)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::EngineConfig;

    fn run(cfg: &RadixConfig, policy: HashPolicy) -> crate::sim::RunStats {
        let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }));
        let p = build(&mut e, cfg);
        p.validate().unwrap();
        e.run(&p, &mut StaticMapper::new()).unwrap()
    }

    #[test]
    fn builds_and_completes_both_variants() {
        for localised in [false, true] {
            let stats = run(
                &RadixConfig {
                    elems: 1 << 14,
                    threads: 8,
                    digit_bits: 8,
                    localised,
                },
                HashPolicy::AllButStack,
            );
            assert!(stats.makespan_cycles > 0);
            assert_eq!(stats.allocs - stats.frees, 2, "only src+dst stay live");
        }
    }

    #[test]
    fn wider_digits_mean_fewer_passes() {
        // 4-bit digits need 8 passes vs 4 for 8-bit; with small histograms
        // either way, chunk-stream traffic should roughly double.
        let s8 = run(
            &RadixConfig { elems: 1 << 14, threads: 4, digit_bits: 8, localised: false },
            HashPolicy::AllButStack,
        );
        let s4 = run(
            &RadixConfig { elems: 1 << 14, threads: 4, digit_bits: 4, localised: false },
            HashPolicy::AllButStack,
        );
        assert!(
            s4.line_accesses > s8.line_accesses,
            "8 passes {} must out-traffic 4 passes {}",
            s4.line_accesses,
            s8.line_accesses
        );
    }

    #[test]
    fn scatter_writes_spread_across_homes() {
        // Radix scatter under hash-for-home should never concentrate on one
        // home tile the way non-localised merge sort does.
        let stats = run(
            &RadixConfig { elems: 1 << 15, threads: 8, digit_bits: 8, localised: false },
            HashPolicy::AllButStack,
        );
        let conc = crate::metrics::home_concentration(&stats);
        assert!(conc < 0.3, "scatter should spread: concentration {conc}");
    }

    #[test]
    fn localisation_helps_radix_under_local_homing() {
        // Algorithm 1 applies to radix's read side (count + scatter source
        // scans): under local homing the localised variant must win. (How
        // its gain *compares* to merge sort's is configuration-dependent —
        // benches/algo_comparison.rs charts that.)
        let elems = 1u64 << 16;
        let conv = run(
            &RadixConfig { elems, threads: 16, digit_bits: 8, localised: false },
            HashPolicy::None,
        );
        let loc = run(
            &RadixConfig { elems, threads: 16, digit_bits: 8, localised: true },
            HashPolicy::None,
        );
        assert!(
            loc.makespan_cycles < conv.makespan_cycles,
            "localised radix {} vs conventional {}",
            loc.makespan_cycles,
            conv.makespan_cycles
        );
    }
}
