//! Parallel LSD radix sort — the comparison baseline from the paper's
//! related work ([3] Morari et al., "Efficient sorting on the Tilera
//! manycore architecture"), which sorted with radix partitioning and
//! fine-grained TMC tuning. Implementing it lets the benches compare the
//! localisation technique across *algorithms*, not just within merge sort.
//!
//! Structure per digit pass (radix 2^B, W/B passes over W-bit keys):
//!   1. count: each thread histograms its chunk (sequential read);
//!   2. prefix: thread 0 combines the 64×2^B histogram matrix (barrier);
//!   3. scatter: each thread re-reads its chunk and writes each key to its
//!      destination bucket — *scattered* writes across the whole output
//!      array, the access pattern that stresses homing policies very
//!      differently from merge sort's sequential streams.
//!
//! The localised variant applies Algorithm 1 to the chunk (copy → local
//! reads), but the scatter writes remain global by nature — which is why
//! radix gains less from localisation than merge sort, matching [3]'s
//! preference for explicit fine-grained control.
//!
//! Each thread's trace is a streaming state machine (one phase of one pass
//! per batch); slot and event numbering is closed-form per (pass, phase),
//! so every thread derives the same global ids without a shared builder.

use crate::arch::TileId;
use crate::mem::AllocKind;
use crate::sim::trace::{OpSource, SegmentGen, SegmentSource};
use crate::sim::{Engine, Loc, Program, TraceBuilder};
use crate::workloads::microbench::part_bounds;

pub const ELEM_BYTES: u64 = 4;

#[derive(Clone, Copy, Debug)]
pub struct RadixConfig {
    pub elems: u64,
    pub threads: usize,
    /// Bits per digit (2^bits buckets); 8 → 4 passes over u32 keys.
    pub digit_bits: u32,
    /// Apply Algorithm 1 to the read side of each pass.
    pub localised: bool,
}

impl Default for RadixConfig {
    fn default() -> Self {
        RadixConfig {
            elems: 1_000_000,
            threads: 63,
            digit_bits: 8,
            localised: false,
        }
    }
}

/// Shared (copyable) parameters of the generated program.
#[derive(Clone, Copy)]
struct GenParams {
    src0: Loc,
    dst0: Loc,
    elems: u64,
    threads: usize,
    localised: bool,
    passes: u32,
    buckets: u64,
    hist_bytes: u64,
}

impl GenParams {
    /// Events per pass: T count signals + 1 prefix + T scatter + 1 barrier.
    fn events_per_pass(&self) -> u32 {
        2 * self.threads as u32 + 2
    }

    fn count_base(&self, pass: u32) -> u32 {
        pass * self.events_per_pass()
    }

    fn prefix_done(&self, pass: u32) -> u32 {
        self.count_base(pass) + self.threads as u32
    }

    fn scatter_base(&self, pass: u32) -> u32 {
        self.prefix_done(pass) + 1
    }

    fn pass_done(&self, pass: u32) -> u32 {
        self.scatter_base(pass) + self.threads as u32
    }

    /// Per-thread stack histogram slot.
    fn hist_slot(&self, i: usize) -> u32 {
        i as u32
    }

    /// Localised chunk-copy slot for `(pass, thread)`.
    fn copy_slot(&self, pass: u32, i: usize) -> u32 {
        self.threads as u32 + pass * self.threads as u32 + i as u32
    }

    /// Double buffer: src/dst swap every pass.
    fn bufs(&self, pass: u32) -> (Loc, Loc) {
        if pass % 2 == 0 {
            (self.src0, self.dst0)
        } else {
            (self.dst0, self.src0)
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Allocate the stack histogram.
    Prologue,
    Count,
    Prefix,
    Scatter,
    Barrier,
    /// Free the histogram.
    Epilogue,
    Done,
}

/// Streaming generator for one radix thread: one phase per batch.
struct ThreadGen {
    i: usize,
    p: GenParams,
    start: u64,
    end: u64,
    pass: u32,
    phase: Phase,
}

impl ThreadGen {
    fn new(i: usize, p: GenParams) -> Self {
        let (start, end) = part_bounds(p.elems, p.threads, i);
        ThreadGen {
            i,
            p,
            start,
            end,
            pass: 0,
            phase: Phase::Prologue,
        }
    }

    fn part_bytes(&self) -> u64 {
        (self.end - self.start) * ELEM_BYTES
    }

    fn hist_loc(&self, j: usize) -> Loc {
        Loc::Slot {
            slot: self.p.hist_slot(j),
            offset: 0,
        }
    }
}

impl SegmentGen for ThreadGen {
    fn fill(&mut self, out: &mut TraceBuilder) -> bool {
        let p = self.p;
        let i = self.i;
        let t = p.threads;
        let part_bytes = self.part_bytes();
        let (cur_src, cur_dst) = p.bufs(self.pass);
        match self.phase {
            Phase::Prologue => {
                out.alloc(p.hist_slot(i), p.hist_bytes, AllocKind::Stack);
                self.phase = Phase::Count;
            }
            Phase::Count => {
                let chunk = cur_src.offset(self.start * ELEM_BYTES);
                let read_from = if p.localised {
                    let local = Loc::Slot {
                        slot: p.copy_slot(self.pass, i),
                        offset: 0,
                    };
                    out.alloc(p.copy_slot(self.pass, i), part_bytes, AllocKind::Heap);
                    out.copy(chunk, local, part_bytes);
                    local
                } else {
                    chunk
                };
                out.read(read_from, part_bytes)
                    .compute(self.end - self.start) // digit extraction + count
                    .write(self.hist_loc(i), p.hist_bytes);
                out.signal(p.count_base(self.pass) + i as u32);
                self.phase = Phase::Prefix;
            }
            Phase::Prefix => {
                // Thread 0 reads all histograms (remote stacks!) and
                // computes global prefix sums — a small all-to-one step.
                if i == 0 {
                    for j in 0..t as u32 {
                        out.wait(p.count_base(self.pass) + j);
                    }
                    for j in 0..t {
                        out.read(self.hist_loc(j), p.hist_bytes);
                    }
                    out.compute(p.buckets * t as u64);
                    for j in 0..t {
                        out.write(self.hist_loc(j), p.hist_bytes);
                    }
                    out.signal(p.prefix_done(self.pass));
                }
                self.phase = Phase::Scatter;
            }
            Phase::Scatter => {
                out.wait(p.prefix_done(self.pass));
                let read_from = if p.localised {
                    // The copy made in the count phase for this pass.
                    Loc::Slot {
                        slot: p.copy_slot(self.pass, i),
                        offset: 0,
                    }
                } else {
                    cur_src.offset(self.start * ELEM_BYTES)
                };
                // Re-read the chunk; writes scatter over the whole
                // destination: model as strided writes across the full dst
                // range (one line per ~buckets/elems stride is unmodelable
                // exactly; bill the same byte volume spread as `runs`
                // separate run writes).
                out.read(read_from, part_bytes)
                    .compute(2 * (self.end - self.start));
                let runs = p.buckets.min(self.end - self.start).max(1);
                let run_bytes = (part_bytes / runs).max(ELEM_BYTES);
                let span = p.elems * ELEM_BYTES - run_bytes;
                for r in 0..runs {
                    // Spread the write targets across dst deterministically.
                    let off = (r * 0x9E37_79B9 + self.pass as u64 * 0x85EB_CA6B)
                        % (span / ELEM_BYTES + 1)
                        * ELEM_BYTES;
                    out.write(cur_dst.offset(off), run_bytes);
                }
                if p.localised {
                    out.free(p.copy_slot(self.pass, i));
                }
                out.signal(p.scatter_base(self.pass) + i as u32);
                self.phase = Phase::Barrier;
            }
            Phase::Barrier => {
                // Everyone waits for all scatters before the next pass
                // (thread 0 aggregates; others wait on thread 0's echo).
                if i == 0 {
                    for j in 1..t as u32 {
                        out.wait(p.scatter_base(self.pass) + j);
                    }
                    out.signal(p.pass_done(self.pass));
                } else {
                    out.wait(p.pass_done(self.pass));
                }
                self.pass += 1;
                self.phase = if self.pass < p.passes {
                    Phase::Count
                } else {
                    Phase::Epilogue
                };
            }
            Phase::Epilogue => {
                out.free(p.hist_slot(i));
                self.phase = Phase::Done;
            }
            Phase::Done => return false,
        }
        true
    }

    fn rewind(&mut self) {
        self.pass = 0;
        self.phase = Phase::Prologue;
    }
}

/// Build the radix-sort program. Uses a double buffer (src/dst swap per
/// pass), both allocated by main; histograms live on each thread's stack.
pub fn build(engine: &mut Engine, cfg: &RadixConfig) -> Program {
    assert!(cfg.threads >= 1 && cfg.elems >= cfg.threads as u64);
    assert!(cfg.digit_bits >= 1 && cfg.digit_bits <= 16);
    let bytes = cfg.elems * ELEM_BYTES;
    let src = engine.prealloc_touched(TileId(0), bytes);
    let dst = engine.prealloc(TileId(0), bytes);
    let passes = 32u32.div_ceil(cfg.digit_bits);
    let buckets = 1u64 << cfg.digit_bits;

    let p = GenParams {
        src0: Loc::Abs(src.addr),
        dst0: Loc::Abs(dst.addr),
        elems: cfg.elems,
        threads: cfg.threads,
        localised: cfg.localised,
        passes,
        buckets,
        hist_bytes: buckets * 8,
    };
    let num_slots = cfg.threads as u32
        + if cfg.localised {
            passes * cfg.threads as u32
        } else {
            0
        };
    let num_events = passes * p.events_per_pass();
    let sources: Vec<Box<dyn OpSource>> = (0..cfg.threads)
        .map(|i| SegmentSource::boxed(ThreadGen::new(i, p)))
        .collect();
    Program::new(sources, num_slots, num_events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::EngineConfig;

    fn run(cfg: &RadixConfig, policy: HashPolicy) -> crate::sim::RunStats {
        let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }));
        let mut p = build(&mut e, cfg);
        p.validate().unwrap();
        e.run(&mut p, &mut StaticMapper::new()).unwrap()
    }

    #[test]
    fn builds_and_completes_both_variants() {
        for localised in [false, true] {
            let stats = run(
                &RadixConfig {
                    elems: 1 << 14,
                    threads: 8,
                    digit_bits: 8,
                    localised,
                },
                HashPolicy::AllButStack,
            );
            assert!(stats.makespan_cycles > 0);
            assert_eq!(stats.allocs - stats.frees, 2, "only src+dst stay live");
        }
    }

    #[test]
    fn streams_replay_identically_after_reset() {
        for localised in [false, true] {
            let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::AllButStack,
                striping: true,
            }));
            let mut p = build(
                &mut e,
                &RadixConfig {
                    elems: 1 << 12,
                    threads: 4,
                    digit_bits: 8,
                    localised,
                },
            );
            assert_eq!(p.record(), p.record(), "localised={localised}");
        }
    }

    #[test]
    fn wider_digits_mean_fewer_passes() {
        // 4-bit digits need 8 passes vs 4 for 8-bit; with small histograms
        // either way, chunk-stream traffic should roughly double.
        let s8 = run(
            &RadixConfig { elems: 1 << 14, threads: 4, digit_bits: 8, localised: false },
            HashPolicy::AllButStack,
        );
        let s4 = run(
            &RadixConfig { elems: 1 << 14, threads: 4, digit_bits: 4, localised: false },
            HashPolicy::AllButStack,
        );
        assert!(
            s4.line_accesses > s8.line_accesses,
            "8 passes {} must out-traffic 4 passes {}",
            s4.line_accesses,
            s8.line_accesses
        );
    }

    #[test]
    fn scatter_writes_spread_across_homes() {
        // Radix scatter under hash-for-home should never concentrate on one
        // home tile the way non-localised merge sort does.
        let stats = run(
            &RadixConfig { elems: 1 << 15, threads: 8, digit_bits: 8, localised: false },
            HashPolicy::AllButStack,
        );
        let conc = crate::metrics::home_concentration(&stats);
        assert!(conc < 0.3, "scatter should spread: concentration {conc}");
    }

    #[test]
    fn localisation_helps_radix_under_local_homing() {
        // Algorithm 1 applies to radix's read side (count + scatter source
        // scans): under local homing the localised variant must win. (How
        // its gain *compares* to merge sort's is configuration-dependent —
        // benches/algo_comparison.rs charts that.)
        let elems = 1u64 << 16;
        let conv = run(
            &RadixConfig { elems, threads: 16, digit_bits: 8, localised: false },
            HashPolicy::None,
        );
        let loc = run(
            &RadixConfig { elems, threads: 16, digit_bits: 8, localised: true },
            HashPolicy::None,
        );
        assert!(
            loc.makespan_cycles < conv.makespan_cycles,
            "localised radix {} vs conventional {}",
            loc.makespan_cycles,
            conv.makespan_cycles
        );
    }
}
