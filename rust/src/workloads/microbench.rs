//! The paper's micro-benchmark (Algorithm 2, Fig. 1).
//!
//! Two arrays of 1 M integers; each of 63 threads repeatedly copies its
//! part of the input to the corresponding part of the output. The
//! *localised* variant first copies its input part into a freshly
//! allocated local array (re-homing it on the worker's tile under
//! `ucache_hash=none`) and streams from that copy instead.
//!
//! Each thread's trace is a streaming generator (one rep materialised at a
//! time), so the simulable array size and repetition count are not bounded
//! by host RAM.

use crate::arch::TileId;
use crate::mem::AllocKind;
use crate::sim::trace::{OpSource, SegmentGen, SegmentSource};
use crate::sim::{Engine, Loc, Program, TraceBuilder};

pub const ELEM_BYTES: u64 = 4;

#[derive(Clone, Copy, Debug)]
pub struct MicrobenchConfig {
    /// Array length in elements (paper: 1_000_000).
    pub elems: u64,
    /// Worker threads (paper: 63).
    pub threads: usize,
    /// Copy repetitions per thread (Fig. 1's x-axis).
    pub reps: u32,
    /// Algorithm 2's two variants.
    pub localised: bool,
}

impl Default for MicrobenchConfig {
    fn default() -> Self {
        MicrobenchConfig {
            elems: 1_000_000,
            threads: 63,
            reps: 16,
            localised: false,
        }
    }
}

/// Element range `[start, end)` of thread `i` out of `m` (paper: each part
/// is `input_size / num_threads`, remainder to the last thread).
pub fn part_bounds(elems: u64, threads: usize, i: usize) -> (u64, u64) {
    let m = threads as u64;
    let base = elems / m;
    let start = base * i as u64;
    let end = if i + 1 == threads { elems } else { start + base };
    (start, end)
}

/// Streaming generator for one worker thread: one copy rep per batch.
struct ThreadGen {
    in_part: Loc,
    out_part: Loc,
    bytes: u64,
    slot: u32,
    reps: u32,
    localised: bool,
    step: u32,
}

impl SegmentGen for ThreadGen {
    fn fill(&mut self, out: &mut TraceBuilder) -> bool {
        let local = Loc::Slot {
            slot: self.slot,
            offset: 0,
        };
        if self.localised {
            // ---- Algorithm 2, localised: ----
            // int* input_cpy = new int[size];
            // memcpy(input_cpy, input1, size*sizeof(int));
            // repetitive_copy(input_cpy, output, size);
            // free(input_cpy);
            match self.step {
                0 => {
                    out.alloc(self.slot, self.bytes, AllocKind::Heap);
                    out.copy(self.in_part, local, self.bytes);
                }
                s if s <= self.reps => {
                    out.copy(local, self.out_part, self.bytes);
                }
                s if s == self.reps + 1 => {
                    out.free(self.slot);
                }
                _ => return false,
            }
        } else {
            // ---- Algorithm 2, non-localised: repetitive_copy(input1, output, size);
            if self.step >= self.reps {
                return false;
            }
            out.copy(self.in_part, self.out_part, self.bytes);
        }
        self.step += 1;
        true
    }

    fn rewind(&mut self) {
        self.step = 0;
    }
}

/// Build the micro-benchmark program against `engine`'s memory system.
///
/// The input array is initialised by `main` (tile 0) — under first-touch
/// that strands it on tile 0; the output array is only ever touched by the
/// workers. This matches the C++: `main` fills `input`, workers fill
/// `output`.
pub fn build(engine: &mut Engine, cfg: &MicrobenchConfig) -> Program {
    assert!(cfg.threads >= 1 && cfg.elems >= cfg.threads as u64);
    let input = engine.prealloc_touched(TileId(0), cfg.elems * ELEM_BYTES);
    let output = engine.prealloc(TileId(0), cfg.elems * ELEM_BYTES);

    let mut sources: Vec<Box<dyn OpSource>> = Vec::with_capacity(cfg.threads);
    for i in 0..cfg.threads {
        let (start, end) = part_bounds(cfg.elems, cfg.threads, i);
        sources.push(SegmentSource::boxed(ThreadGen {
            in_part: Loc::Abs(input.addr.offset(start * ELEM_BYTES)),
            out_part: Loc::Abs(output.addr.offset(start * ELEM_BYTES)),
            bytes: (end - start) * ELEM_BYTES,
            slot: i as u32,
            reps: cfg.reps,
            localised: cfg.localised,
            step: 0,
        }));
    }
    Program::new(sources, cfg.threads as u32, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::EngineConfig;

    fn engine(policy: HashPolicy) -> Engine {
        Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }))
    }

    fn cfg(localised: bool, reps: u32) -> MicrobenchConfig {
        MicrobenchConfig {
            elems: 64 * 1024, // keep unit tests fast
            threads: 16,
            reps,
            localised,
        }
    }

    #[test]
    fn part_bounds_cover_exactly() {
        let (elems, threads) = (1_000_000u64, 63usize);
        let mut covered = 0;
        for i in 0..threads {
            let (s, e) = part_bounds(elems, threads, i);
            assert!(e > s);
            assert_eq!(s, covered);
            covered = e;
        }
        assert_eq!(covered, elems);
    }

    #[test]
    fn program_validates_both_variants() {
        for localised in [false, true] {
            let mut e = engine(HashPolicy::None);
            let mut p = build(&mut e, &cfg(localised, 3));
            p.validate().unwrap();
            assert_eq!(p.threads.len(), 16);
        }
    }

    #[test]
    fn stream_replays_identically_after_reset() {
        let mut e = engine(HashPolicy::None);
        let mut p = build(&mut e, &cfg(true, 3));
        let first = p.record();
        let second = p.record();
        assert_eq!(first, second);
        // Localised thread stream: alloc+copy, 3 copies, free.
        assert_eq!(first[0].len(), 2 + 3 + 1);
    }

    #[test]
    fn localised_variant_allocates_and_frees() {
        let mut e = engine(HashPolicy::None);
        let mut p = build(&mut e, &cfg(true, 2));
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert_eq!(stats.allocs, 2 + 16); // input+output preallocs + 16 copies
        assert_eq!(stats.frees, 16);
    }

    #[test]
    fn localised_beats_non_localised_under_local_homing() {
        // The paper's headline (Fig. 1): with hash disabled and enough
        // repetitions, localisation wins clearly.
        let mut e1 = engine(HashPolicy::None);
        let mut p1 = build(&mut e1, &cfg(false, 16));
        let non_loc = e1.run(&mut p1, &mut StaticMapper::new()).unwrap();

        let mut e2 = engine(HashPolicy::None);
        let mut p2 = build(&mut e2, &cfg(true, 16));
        let loc = e2.run(&mut p2, &mut StaticMapper::new()).unwrap();

        assert!(
            loc.makespan_cycles * 2 < non_loc.makespan_cycles,
            "localised {} vs non-localised {}",
            loc.makespan_cycles,
            non_loc.makespan_cycles
        );
    }

    #[test]
    fn localisation_neutral_under_hash_for_home() {
        // Paper §5: localisation "does not lose the competition" under
        // hash-for-home (within copy-overhead slack).
        let mut e1 = engine(HashPolicy::AllButStack);
        let mut p1 = build(&mut e1, &cfg(false, 16));
        let non_loc = e1.run(&mut p1, &mut StaticMapper::new()).unwrap();

        let mut e2 = engine(HashPolicy::AllButStack);
        let mut p2 = build(&mut e2, &cfg(true, 16));
        let loc = e2.run(&mut p2, &mut StaticMapper::new()).unwrap();

        let ratio = loc.makespan_cycles as f64 / non_loc.makespan_cycles as f64;
        assert!(ratio < 1.3, "localised must not lose badly under hash: {ratio}");
    }

    #[test]
    fn single_rep_favours_non_localised() {
        // Fig. 1 at very low repetition counts: the copy isn't amortised.
        let mut e1 = engine(HashPolicy::None);
        let mut p1 = build(&mut e1, &cfg(false, 1));
        let non_loc = e1.run(&mut p1, &mut StaticMapper::new()).unwrap();

        let mut e2 = engine(HashPolicy::None);
        let mut p2 = build(&mut e2, &cfg(true, 1));
        let loc = e2.run(&mut p2, &mut StaticMapper::new()).unwrap();

        // The localised run does strictly more memory work at reps=1.
        assert!(loc.line_accesses > non_loc.line_accesses);
    }
}
