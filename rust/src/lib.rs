//! # tilesim — cache-aware parallel programming for manycore processors
//!
//! A reproduction of Tousimojarad & Vanderbauwhede, *Cache-aware Parallel
//! Programming for Manycore Processors* (CS.DC 2014): the *localisation*
//! programming technique for NUCA manycores, validated on a from-scratch
//! cycle-approximate simulator parameterised by a runtime machine
//! description ([`arch::Machine`]: any W×H mesh with a controller
//! placement strategy ([`arch::CtrlPlacement`]), a heterogeneous per-link
//! fabric ([`arch::Fabric`] — express rows/columns, per-direction
//! asymmetry), a per-machine clock, and per-link contention; the Tilera
//! TILEPro64 — 8×8 mesh, DDC distributed home caches, 4 striped
//! controllers — is the default preset), plus a Rust+JAX+Pallas compute
//! runtime whose AOT-compiled
//! sorting kernels mirror the paper's merge-sort workload on the request
//! path.
//!
//! Layer map (see `docs/ARCHITECTURE.md` for the contributor guide):
//! - **L3 (this crate)** — the coordinator: simulator substrates
//!   ([`arch`], [`mem`], [`cache`], [`noc`], [`sim`], [`sched`]), the
//!   localisation API and experiment matrix ([`coordinator`]), the paper's
//!   workloads ([`workloads`]), the open-loop serve front-end ([`serve`] —
//!   seeded arrivals, bounded queueing, latency percentiles, saturation
//!   knees), and the PJRT runtime ([`runtime`]).
//! - **L2/L1 (python/compile)** — JAX chunked sorter calling Pallas bitonic
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`, executed by
//!   [`runtime`] with Python never on the request path.
//!
//! Figure-by-figure reproduction commands live in `docs/REPRO.md`.

pub mod arch;
pub mod cache;
pub mod coherence;
pub mod coordinator;
pub mod harness;
pub mod mem;
pub mod metrics;
pub mod noc;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workloads;
